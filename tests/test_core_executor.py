"""Execution planning: Coalesce buckets, ExecutionPlan waves, program_time.

Covers the plan invariants (waves are topological; program_time is
bounded by the longest single stage below and the serial stage sum
above), the Coalesce acceptance shape (a ≥64-ragged-leaf sync compiles
to ⌈total/bucket⌉ + O(1) collective stages, not one per leaf), and the
numerics: bucketized gradient_sync is allclose to both the per-leaf acis
sync and the xla pmean path on all four acis backends, error-feedback
residual state included.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import core as acis
from repro.core import make_engine, netmodel, tracing
from repro.core.executor import ExecutionPlan, build_plan

AV = jax.ShapeDtypeStruct
N = 8

BACKENDS = ["acis", "acis_compressed", "acis_hierarchical",
            "acis_hierarchical_compressed"]


@pytest.fixture(scope="module")
def mesh22():
    return jax.make_mesh((2, 2), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def smap(fn, mesh, in_specs, out_specs):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


def _sync_program(engine, sizes, axis_sizes, n_total):
    def sync(*gs):
        outs = []
        for g in gs:
            r = tracing.reduce(g, axis="auto")
            outs.append(tracing.map(lambda y: y / n_total, r, name="mean"))
        return tuple(outs)

    prog = tracing.trace(sync, num_inputs=len(sizes))
    return engine.compile(
        prog, in_avals=tuple(AV((s,), jnp.float32) for s in sizes),
        axis_size=axis_sizes)


def _collective_stages(compiled):
    return [s for s in compiled.stages if s.kind not in ("map", "delivered")]


# ---------------------------------------------------------------------------
# ExecutionPlan structure
# ---------------------------------------------------------------------------

def test_plan_waves_are_topological_and_cover_all_stages():
    eng = make_engine("acis", outer_axis="pod")
    c = eng.compile(lambda x: acis.reduce(x, axis="auto"),
                    in_avals=(AV((256,), jnp.float32),),
                    axis_size={"data": 4, "pod": 2})
    plan = c.plan
    assert isinstance(plan, ExecutionPlan)
    assert plan.n_stages == len(c.stages)
    plan.validate()                     # waves topological, full cover
    # the hierarchical chain is fully sequential: one stage per wave
    assert plan.n_waves == len(c.stages)
    for i, deps in enumerate(plan.deps):
        for d in deps:
            assert plan.wave_of(d) < plan.wave_of(i)


def test_independent_stages_share_a_wave():
    eng = make_engine("acis")
    sizes = [64, 96, 32]
    c = _sync_program(eng, sizes, {"data": N}, N)
    # pack → bucket AR → 3 splits (one wave) → 3 means (one wave)
    assert c.plan.n_waves == 4
    split_wave = c.plan.waves[2]
    assert len(split_wave) == len(sizes)


def test_build_plan_rejects_double_definition():
    class FakeStage:
        def __init__(self, ins, outs):
            self.in_vids, self.out_vids = ins, outs

    plan = build_plan([FakeStage((0,), (1,)), FakeStage((1,), (2,))], 1, (2,))
    assert plan.deps == ((), (0,))
    assert plan.waves == ((0,), (1,))

    with pytest.raises(ValueError, match="single-assignment"):
        build_plan([FakeStage((0,), (1,)), FakeStage((0,), (1,))], 1, (1,))


def test_compiled_program_always_returns_tuple(mesh8, rng):
    """Single-output programs return a 1-tuple — no more bare-array
    special case at the call boundary."""
    eng = make_engine("acis")
    c = eng.compile(lambda x: acis.reduce(x))
    x = rng.standard_normal((N, 16)).astype(np.float32)
    out = smap(lambda v: c(v[0])[0][None], mesh8, P("data", None),
               P("data", None))(jnp.asarray(x))
    got = np.asarray(out)
    for i in range(N):
        np.testing.assert_allclose(got[i], x.sum(0), rtol=1e-5)

    def check_tuple(v):
        res = c(v[0])
        assert isinstance(res, tuple) and len(res) == 1
        return res[0][None]

    smap(check_tuple, mesh8, P("data", None), P("data", None))(
        jnp.asarray(x))


def test_explain_reports_waves():
    eng = make_engine("acis")
    c = _sync_program(eng, [64, 96], {"data": N}, N)
    txt = c.explain()
    assert "wave" in txt
    assert f"{c.plan.n_waves} waves" in txt


# ---------------------------------------------------------------------------
# program_time bounds (the plan-invariant property)
# ---------------------------------------------------------------------------

def _assert_program_time_bounds(compiled):
    times = [netmodel.plan_stage_time(s, compiled.topology)
             for s in compiled.stages]
    known = [t for t in times if t]
    assert known, "no stage is costable — the property is vacuous"
    t = compiled.program_time()
    eps = 1e-12
    assert t >= max(known) - eps
    assert t <= sum(known) + eps


@pytest.mark.parametrize("backend", BACKENDS)
def test_program_time_bounded_by_max_and_sum(backend):
    hier = "hierarchical" in backend
    eng = make_engine(backend, inner_axis="data",
                      outer_axis="pod" if hier else None)
    sizes = [257, 1024, 33, 4096, 129, 65536]
    axis_sizes = {"data": 4, "pod": 2} if hier else {"data": N}
    c = _sync_program(eng, sizes, axis_sizes, N)
    _assert_program_time_bounds(c)


def test_program_time_bounds_hold_for_random_leaf_mixes():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    eng = make_engine("acis")

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.integers(min_value=1, max_value=1 << 16),
                    min_size=1, max_size=12),
           st.sampled_from([0, None, 4096]))
    def prop(sizes, bucket_override):
        e = make_engine("acis", bucket_bytes=bucket_override) \
            if bucket_override is not None else eng
        c = _sync_program(e, sizes, {"data": N}, N)
        _assert_program_time_bounds(c)

    prop()


def test_program_time_beats_serial_sum_when_axes_overlap():
    """Two same-wave stages on different axes must overlap: the plan cost
    is strictly below the serial sum of the two collectives."""
    eng = make_engine("acis", outer_axis="pod")

    def prog(x, y):
        return (acis.reduce(x, axis="data"), acis.reduce(y, axis="pod"))

    c = eng.compile(prog, in_avals=(AV((1 << 15,), jnp.float32),) * 2,
                    axis_size={"data": 4, "pod": 2})
    times = [netmodel.plan_stage_time(s, c.topology) for s in c.stages]
    assert all(times)
    assert c.plan.n_waves == 1
    assert c.program_time() < sum(times) - 1e-9


# ---------------------------------------------------------------------------
# Coalesce: stage-count acceptance + structure
# ---------------------------------------------------------------------------

def _ragged_sizes(n):
    r = np.random.default_rng(3)
    return [int(r.integers(1 << 6, 1 << 14)) for _ in range(n)]


def test_64_leaf_sync_compiles_to_bucket_count_collectives():
    """Acceptance: ≥64 ragged leaves → ≤ ⌈total/bucket⌉ + O(1) collective
    stages instead of one per leaf."""
    sizes = _ragged_sizes(64)
    eng = make_engine("acis")
    c = _sync_program(eng, sizes, {"data": N}, N)
    total_bytes = sum(sizes) * 4
    cap = netmodel.bucket_bytes(N)
    n_coll = len(_collective_stages(c))
    assert n_coll <= math.ceil(total_bytes / cap) + 2
    assert n_coll < 64

    per_leaf = _sync_program(make_engine("acis", bucket_bytes=0),
                             sizes, {"data": N}, N)
    assert len(_collective_stages(per_leaf)) == 64
    # ...and the planner prices the bucketized program below per-leaf
    assert c.program_time() < per_leaf.program_time()


def test_bucket_bytes_override_controls_bucket_count():
    sizes = [1024] * 8                            # 4 KB leaves
    eng = make_engine("acis", bucket_bytes=8192)  # 2 leaves per bucket
    c = _sync_program(eng, sizes, {"data": N}, N)
    assert len(_collective_stages(c)) == 4
    packs = [s for s in c.stages
             if s.ir.nodes[0].op.name == "bucket_pack"]
    assert len(packs) == 4


def test_coalesce_skips_unknown_avals_and_mixed_groups():
    eng = make_engine("acis")
    # no in_avals → no bucketing, program still compiles and runs
    c = eng.compile(lambda x, y: (acis.reduce(x), acis.reduce(y)))
    assert len(_collective_stages(c)) == 2
    # different monoids must not share a bucket
    c2 = eng.compile(
        lambda x, y: (acis.reduce(x, acis.MAX), acis.reduce(y)),
        in_avals=(AV((64,), jnp.float32),) * 2, axis_size=N)
    assert len(_collective_stages(c2)) == 2


def test_dependent_reduces_never_share_a_bucket(mesh8, rng):
    """A reduce feeding another reduce with the same axis/monoid/codec
    must not be packed into one bucket (the pack would consume a value
    the bucket itself produces) — regression: this used to KeyError in
    the Coalesce rewrite."""
    eng = make_engine("acis")

    def prog(x, y):
        a = acis.reduce(x, axis="data")
        b = acis.reduce(acis.map(lambda v: v * 0.5, a, name="h"),
                        axis="data")
        c = acis.reduce(y, axis="data")
        return a, b, c

    c = eng.compile(tracing.trace(prog),
                    in_avals=(AV((16,), jnp.float32),) * 2, axis_size=N)
    c.source.validate()
    x = rng.standard_normal((N, 16)).astype(np.float32)
    y = rng.standard_normal((N, 16)).astype(np.float32)
    outs = smap(lambda a, b: tuple(o[None] for o in c(a[0], b[0])),
                mesh8, (P("data", None),) * 2, (P("data", None),) * 3)(
        jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(outs[0])[0], x.sum(0), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(outs[1])[0],
                               N * 0.5 * x.sum(0), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(outs[2])[0], y.sum(0), rtol=1e-4)


def test_topk_ef_is_never_bucketized():
    """Global top-k over a concat bucket would starve small-magnitude
    leaves — Coalesce must leave top-k EF reductions per-leaf."""
    eng = make_engine("acis_compressed", compressor="topk")

    def prog(x, y):
        return (acis.ef_reduce(x, axis="data", compressor="topk")[0],
                acis.ef_reduce(y, axis="data", compressor="topk")[0])

    c = eng.compile(tracing.trace(prog),
                    in_avals=(AV((64,), jnp.float32),) * 2, axis_size=N)
    assert c.stage_kinds().count("ef_allreduce") == 2
    assert not any(s.kind == "map" and s.ir.nodes[0].op.name == "bucket_pack"
                   for s in c.stages)


def test_hierarchical_chains_bucketize_whole(mesh22):
    """Multi-axis leaves bucket as whole pad→RS→AR→AG→unpad chains: one
    hierarchical triple for the bucket, codec still on the outer hop."""
    from repro.core.program import OpKind
    from repro.core.wire import IDENTITY

    eng = make_engine("acis_hierarchical_compressed", inner_axis="data",
                      outer_axis="pod")
    c = _sync_program(eng, [33, 257, 65], {"data": 2, "pod": 2}, 4)
    kinds = c.stage_kinds()
    assert kinds.count("reduce_scatter") == 1
    assert kinds.count("allreduce") == 1
    assert kinds.count("allgather") == 1
    red = next(nd.op for nd in c.source.nodes
               if nd.op.kind == OpKind.REDUCE)
    rs = next(nd.op for nd in c.source.nodes
              if nd.op.kind == OpKind.REDUCE_SCATTER)
    assert red.axis == "pod" and red.codec is not IDENTITY
    assert rs.codec is IDENTITY


# ---------------------------------------------------------------------------
# numerics: bucketized sync == per-leaf sync == xla pmean (EF state incl.)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_bucketized_sync_matches_per_leaf_and_xla(mesh22, rng, backend):
    n_leaves = 9
    shapes = [(4, 3 + 7 * i) for i in range(n_leaves)]
    grads = {f"l{i}": rng.standard_normal((4,) + s).astype(np.float32)
             for i, s in enumerate(shapes)}
    keys = sorted(grads)

    def run(eng):
        def f(*ls):
            g = {k: l[0, 0] for k, l in zip(keys, ls)}
            state = eng.init_state(g)
            synced, new_state = eng.gradient_sync(g, state)
            outs = [synced[k][None, None] for k in keys]
            if state is not None:
                outs += [new_state[k][None, None] for k in keys]
            return tuple(outs)

        spec = P("pod", "data", None, None)
        n_out = n_leaves * (2 if eng.needs_residual() else 1)
        args = [jnp.asarray(grads[k].reshape((2, 2) + s))
                for k, s in zip(keys, shapes)]
        outs = smap(f, mesh22, (spec,) * n_leaves, (spec,) * n_out)(*args)
        return [np.asarray(o)[0, 0] for o in outs]

    bucketized = run(make_engine(backend, inner_axis="data",
                                 outer_axis="pod"))
    per_leaf = run(make_engine(backend, inner_axis="data",
                               outer_axis="pod", bucket_bytes=0))
    xla = run(make_engine("xla", inner_axis="data", outer_axis="pod"))

    atol = 5e-2 if "compressed" in backend else 1e-4
    for i, k in enumerate(keys):
        want = grads[k].mean(0)
        np.testing.assert_allclose(bucketized[i], want, atol=atol,
                                   err_msg=f"{k} vs xla")
        np.testing.assert_allclose(bucketized[i], xla[i], atol=atol)
        np.testing.assert_allclose(bucketized[i], per_leaf[i], atol=atol)
    if "compressed" in backend:
        # EF residual state: real (nonzero), finite, and consistent with
        # the per-leaf compression path
        for i in range(n_leaves):
            rb = bucketized[n_leaves + i]
            rp = per_leaf[n_leaves + i]
            assert np.all(np.isfinite(rb))
            assert 0 < np.abs(rb).max() < 0.1
            np.testing.assert_allclose(rb, rp, atol=atol)


def test_64_leaf_bucketized_sync_matches_xla_end_to_end(mesh8, rng):
    """The acceptance workload executed for real: 64 ragged leaves sync
    through the bucketized program and match pmean."""
    sizes = _ragged_sizes(64)
    eng = make_engine("acis", inner_axis="data")
    grads = [rng.standard_normal((N, s)).astype(np.float32) for s in sizes]

    def f(*ls):
        g = {f"l{i:02d}": l[0] for i, l in enumerate(ls)}
        synced, _ = eng.gradient_sync(g, None)
        return tuple(synced[f"l{i:02d}"][None] for i in range(len(ls)))

    spec = P("data", None)
    outs = smap(f, mesh8, (spec,) * 64, (spec,) * 64)(
        *[jnp.asarray(g) for g in grads])
    for g, o in zip(grads, outs):
        np.testing.assert_allclose(np.asarray(o)[0], g.mean(0), atol=1e-4)
    compiled = next(iter(eng._sync_cache.values()))
    assert len(_collective_stages(compiled)) < 64


# ---------------------------------------------------------------------------
# simulator overlap validates the analytic model
# ---------------------------------------------------------------------------

def test_simulated_overlap_tracks_program_time():
    from repro.cgra.simulate import SwitchSim

    eng = make_engine("acis")
    sizes = [513, 2048, 131, 4096, 67, 1024, 257, 4095]
    c = _sync_program(eng, sizes, {"data": 4}, 4)
    rng = np.random.default_rng(0)
    inputs = [rng.standard_normal((4, s)).astype(np.float32)
              for s in sizes]
    outs, report = SwitchSim(eng.topology(axis_size=4)).run(c, *inputs)
    for g, o in zip(inputs, outs):
        np.testing.assert_allclose(o[0], g.mean(0), atol=1e-4)
    # overlapped end-to-end ≤ serial stage sum, and the analytic plan
    # prediction lands in the same regime as the simulated latency
    assert report.t_end <= report.t_sim + 1e-12
    assert report.t_program_model is not None
    assert 0.2 < report.t_end / report.t_program_model < 5.0
    waves = {s.wave for s in report.stages}
    assert waves == set(range(c.plan.n_waves))
