"""Pallas bulk data path: kernel/registry parity and compiled equivalence.

Four layers of guarantees:

1. every ``switchops`` registry op carrying a Pallas kernel matches its
   ``kernels/ref.py`` oracle in interpret mode across dtypes (f32 / bf16 /
   int8 where the op admits it) and ragged sizes;
2. a program compiled with ``use_kernels=True`` is numerically equal to
   the default lowering on all four acis backends, error-feedback
   residual state included, arenas included;
3. the Coalesce ``batch_rings`` rewrite is **bit-compatible** with
   per-program ring launches (bandwidth and latency schedules both), and
   RS/AG buckets are bit-compatible with their per-leaf collectives;
4. the cost model covers the new ``batched_allreduce`` stage kind (the
   analytic time stays simulator-checkable) and the amortization helpers
   are sane.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import core as acis
from repro.core import make_engine, netmodel, switchops, tracing
from repro.core.types import ADD, MAX, MIN
from repro.core import ring as ring_mod
from repro.kernels import ops as kops, ref as kref

AV = jax.ShapeDtypeStruct
N = 8

ACIS_BACKENDS = ["acis", "acis_compressed", "acis_hierarchical",
                 "acis_hierarchical_compressed"]

# sizes chosen to exercise the kernels' lane padding: primes, non-128
# multiples, and one aligned size
RAGGED = [7, 129, 1000, 2048]


@pytest.fixture(scope="module")
def mesh22():
    return jax.make_mesh((2, 2), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def smap(fn, mesh, in_specs, out_specs):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


def _tol(dtype, name=None):
    if dtype == jnp.bfloat16:
        return dict(rtol=2e-2, atol=2e-2)
    if name == "prefix_sum":
        # the kernel's blocked scan associates differently from the
        # oracle's cumsum — long prefixes accumulate ~1 ulp per block
        return dict(rtol=1e-3, atol=1e-5)
    return dict(rtol=1e-5, atol=1e-6)


def _data(rng, size, dtype):
    if dtype == jnp.int8:
        return jnp.asarray(rng.integers(-40, 40, size=(size,)), jnp.int8)
    return jnp.asarray(rng.standard_normal((size,)), dtype)


# ---------------------------------------------------------------------------
# 1. registry parity: every kernel-carrying op vs its oracle
# ---------------------------------------------------------------------------

def _combine_args(rng, size, dtype):
    return (_data(rng, size, dtype), _data(rng, size, dtype)), {}


def _mac_args(rng, size, dtype):
    return (_data(rng, size, dtype), _data(rng, size, dtype)), \
        {"alpha": 0.5}


def _prefix_args(rng, size, dtype):
    return (_data(rng, size, dtype),), {}


def _topk_args(rng, size, dtype):
    k = max(size // 4, 1)
    dense = _data(rng, size, dtype)
    idx = jnp.asarray(rng.integers(0, size, size=(k,)), jnp.int32)
    vals = _data(rng, k, dtype)
    return (dense, idx, vals), {}


def _pack_args(rng, size, dtype):
    arena = _data(rng, size, dtype)
    cuts = sorted(set(rng.integers(1, size, size=2).tolist()))
    parts, lo = [], 0
    for hi in cuts + [size]:
        if hi > lo:
            parts.append(_data(rng, hi - lo, dtype))
            lo = hi
    return (arena, *parts), {"op": "add"}


# name → (arg factory, dtypes the op admits)
_REGISTRY_CASES = {
    "add": (_combine_args, (jnp.float32, jnp.bfloat16, jnp.int8)),
    "max": (_combine_args, (jnp.float32, jnp.bfloat16, jnp.int8)),
    "min": (_combine_args, (jnp.float32, jnp.bfloat16, jnp.int8)),
    "mac": (_mac_args, (jnp.float32, jnp.bfloat16)),
    "prefix_sum": (_prefix_args, (jnp.float32,)),
    "topk_accumulate": (_topk_args, (jnp.float32,)),
    "pack_combine": (_pack_args, (jnp.float32, jnp.bfloat16, jnp.int8)),
}


def test_every_registry_kernel_has_a_parity_case():
    """If load_kernels() grows an op, this file must grow its sweep."""
    switchops.load_kernels()
    with_kernel = {n for n in switchops.names()
                   if switchops.get(n).kernel is not None}
    assert with_kernel <= set(_REGISTRY_CASES), \
        f"untested kernels: {with_kernel - set(_REGISTRY_CASES)}"


@pytest.mark.parametrize("size", RAGGED)
@pytest.mark.parametrize("name", sorted(_REGISTRY_CASES))
def test_registry_kernel_matches_ref(rng, name, size):
    switchops.load_kernels()
    op = switchops.get(name)
    assert op.kernel is not None
    factory, dtypes = _REGISTRY_CASES[name]
    for dtype in dtypes:
        args, kw = factory(rng, size, dtype)
        got = op(*args, use_kernel=True, **kw)
        want = op(*args, use_kernel=False, **kw)
        got = jax.tree.leaves(got)
        want = jax.tree.leaves(want)
        for g, w in zip(got, want):
            assert g.shape == w.shape and g.dtype == w.dtype
            np.testing.assert_allclose(
                np.asarray(g, np.float32), np.asarray(w, np.float32),
                **_tol(dtype, name))


@pytest.mark.parametrize("op", [None, "add", "max", "min"])
def test_fused_pack_combine_vs_oracle(rng, op):
    """The fused pack+combine kernel directly vs the ref oracle, with a
    cross-dtype part (f32 leaf into a bf16 arena) and arena tail lanes
    that must survive the aliased write."""
    from repro.kernels import pack_combine as pc

    arena = jnp.asarray(rng.standard_normal((64,)), jnp.bfloat16)
    parts = [jnp.asarray(rng.standard_normal((s,)), jnp.float32)
             for s in (17, 5, 30)]
    got = pc.fused_pack(arena, *[p.astype(arena.dtype) for p in parts],
                        op=op, interpret=True)
    want = kref.pack_combine(arena, *parts, op=op)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)
    # lanes past sum(parts)=52 carry the original arena contents
    np.testing.assert_array_equal(np.asarray(got[52:], np.float32),
                                  np.asarray(arena[52:], np.float32))


def test_fused_pack_overflow_rejected():
    from repro.kernels import pack_combine as pc

    with pytest.raises(ValueError, match="overflows"):
        pc.fused_pack(jnp.zeros((8,)), jnp.ones((9,)), interpret=True)


# ---------------------------------------------------------------------------
# satellite: _interpret_default re-checks per call + env override
# ---------------------------------------------------------------------------

def test_interpret_default_env_override(monkeypatch):
    monkeypatch.delenv("ACIS_KERNEL_INTERPRET", raising=False)
    # CPU container: the backend heuristic says interpret
    assert kops._interpret_default() is True
    monkeypatch.setenv("ACIS_KERNEL_INTERPRET", "0")
    assert kops._interpret_default() is False
    monkeypatch.setenv("ACIS_KERNEL_INTERPRET", "1")
    assert kops._interpret_default() is True
    monkeypatch.setenv("ACIS_KERNEL_INTERPRET", "")
    assert kops._interpret_default() is True    # empty = unset


def test_interpret_default_not_cached(monkeypatch):
    """The old functools.cache pinned the first answer for the process
    lifetime; the env override must take effect on the *next* call."""
    monkeypatch.delenv("ACIS_KERNEL_INTERPRET", raising=False)
    first = kops._interpret_default()
    monkeypatch.setenv("ACIS_KERNEL_INTERPRET", "0")
    assert kops._interpret_default() is False
    monkeypatch.delenv("ACIS_KERNEL_INTERPRET", raising=False)
    assert kops._interpret_default() == first


# ---------------------------------------------------------------------------
# satellite: monoid-identity padding (non-add reductions over ragged sizes)
# ---------------------------------------------------------------------------

def test_pad_to_multiple_uses_monoid_identity():
    x = jnp.asarray([3.0, -7.0, 5.0])
    padded, size = ring_mod.pad_to_multiple(x, 4, monoid=MIN)
    assert size == 3
    assert float(padded[3]) == float(jnp.finfo(jnp.float32).max)
    padded, _ = ring_mod.pad_to_multiple(x, 4, monoid=MAX)
    assert float(padded[3]) == float(jnp.finfo(jnp.float32).min)
    padded, _ = ring_mod.pad_to_multiple(x, 4, monoid=ADD)
    assert float(padded[3]) == 0.0


@pytest.mark.parametrize("monoid", [MAX, MIN], ids=["max", "min"])
def test_ragged_nonadd_reduce_bitwise_correct(mesh8, rng, monoid):
    """A bandwidth-ring MAX/MIN over a size the ring must pad: literal-0
    padding would corrupt all-negative (resp. all-positive) data."""
    sign = -1.0 if monoid.name == "max" else 1.0
    x = sign * np.abs(rng.standard_normal((N, 13))).astype(np.float32) - 1.0
    eng = make_engine("acis", latency_optimal_below=0)  # force bandwidth
    c = eng.compile(lambda v: acis.reduce(v, monoid, axis="data"),
                    in_avals=(AV((13,), jnp.float32),), axis_size=N)
    out = smap(lambda v: c(v[0])[0][None], mesh8, P("data", None),
               P("data", None))(jnp.asarray(x))
    want = x.max(0) if monoid.name == "max" else x.min(0)
    np.testing.assert_array_equal(np.asarray(out)[0], want)


# ---------------------------------------------------------------------------
# 2. compiled programs: use_kernels=True == default path, all backends
# ---------------------------------------------------------------------------

def _run_sync(eng, mesh22, grads, keys, shapes):
    n_leaves = len(keys)

    def f(*ls):
        g = {k: l[0, 0] for k, l in zip(keys, ls)}
        state = eng.init_state(g)
        synced, new_state = eng.gradient_sync(g, state)
        outs = [synced[k][None, None] for k in keys]
        if state is not None:
            outs += [new_state[k][None, None] for k in keys]
        return tuple(outs)

    spec = P("pod", "data", None, None)
    n_out = n_leaves * (2 if eng.needs_residual() else 1)
    args = [jnp.asarray(grads[k].reshape((2, 2) + s))
            for k, s in zip(keys, shapes)]
    outs = smap(f, mesh22, (spec,) * n_leaves, (spec,) * n_out)(*args)
    return [np.asarray(o)[0, 0] for o in outs]


@pytest.mark.parametrize("backend", ACIS_BACKENDS)
def test_use_kernels_matches_default_path(mesh22, rng, backend):
    shapes = [(4, 3 + 7 * i) for i in range(5)]
    grads = {f"l{i}": rng.standard_normal((4,) + s).astype(np.float32)
             for i, s in enumerate(shapes)}
    keys = sorted(grads)
    hier = dict(inner_axis="data", outer_axis="pod")
    with_k = _run_sync(make_engine(backend, use_kernels=True, **hier),
                       mesh22, grads, keys, shapes)
    without = _run_sync(make_engine(backend, use_kernels=False, **hier),
                        mesh22, grads, keys, shapes)
    for i, (a, b) in enumerate(zip(with_k, without)):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7,
                                   err_msg=f"output {i}")


def test_use_kernels_arena_path(mesh8, rng):
    """The fused arena-aliased pack (one Pallas launch instead of N
    dynamic_update_slice calls) under real arenas."""
    sizes = [97, 260, 31]
    grads = {f"l{i}": rng.standard_normal((N, s)).astype(np.float32)
             for i, s in enumerate(sizes)}
    keys = sorted(grads)
    outs = {}
    for uk in (False, True):
        eng = make_engine("acis", use_kernels=uk)

        def f(*ls):
            g = dict(zip(keys, [l[0] for l in ls]))
            ar = eng.init_arenas(g)
            synced, _, _ = eng.gradient_sync(g, None, arenas=ar)
            return tuple(synced[k][None] for k in keys)

        spec = P("data", None)
        outs[uk] = smap(f, mesh8, (spec,) * 3, (spec,) * 3)(
            *[jnp.asarray(grads[k]) for k in keys])
    for k, a, b in zip(keys, outs[True], outs[False]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7, err_msg=k)


def test_use_kernels_in_cache_key():
    a = make_engine("acis", use_kernels=True).config.cache_key()
    b = make_engine("acis", use_kernels=False).config.cache_key()
    c = make_engine("acis", batch_rings=True).config.cache_key()
    d = make_engine("acis").config.cache_key()
    assert a != b and c != d


# ---------------------------------------------------------------------------
# 3a. batched same-axis rings: bit-compatible, stage collapse
# ---------------------------------------------------------------------------

def _batch_prog(monoid):
    def prog(a, b, c):
        return (acis.reduce(a, monoid, axis="data"),
                acis.reduce(b, monoid, axis="data"),
                acis.reduce(c, monoid, axis="data"))
    return prog


@pytest.mark.parametrize("latency_below", [0, 1 << 30],
                         ids=["bandwidth", "latency"])
@pytest.mark.parametrize("monoid", [ADD, MAX], ids=["add", "max"])
def test_batched_ring_bitwise(mesh8, rng, monoid, latency_below):
    """k same-axis rings merged into one launch return bit-identical
    results under both ring schedules (chunk-aligned interleave: every
    lane keeps its fold order)."""
    avals = (AV((45,), jnp.float32), AV((16,), jnp.float32),
             AV((131,), jnp.float32))
    xs = [rng.standard_normal((N,) + a.shape).astype(np.float32) * 0.7
          for a in avals]
    outs = {}
    for br in (False, True):
        eng = make_engine("acis", batch_rings=br, bucket_bytes=0,
                          latency_optimal_below=latency_below)
        c = eng.compile(tracing.trace(_batch_prog(monoid)),
                        in_avals=avals, axis_size=N)
        kinds = c.stage_kinds()
        if br:
            assert kinds.count("batched_allreduce") == 1
            assert "allreduce" not in kinds
        else:
            assert kinds.count("allreduce") == 3
        spec = P("data", None)
        outs[br] = smap(
            lambda *vs: tuple(o[None] for o in c(*[v[0] for v in vs])),
            mesh8, (spec,) * 3, (spec,) * 3)(*[jnp.asarray(x) for x in xs])
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(outs[True][i]),
                                      np.asarray(outs[False][i]))


def test_batched_ring_composes_with_buckets(mesh8, rng):
    """Small buckets leave several same-axis bucket allreduces; batching
    merges them into one launch and the sync stays exact."""
    sizes = [64, 96, 32, 80, 48]
    grads = {f"l{i}": rng.standard_normal((N, s)).astype(np.float32)
             for i, s in enumerate(sizes)}
    keys = sorted(grads)
    outs = {}
    for br in (False, True):
        eng = make_engine("acis", batch_rings=br, bucket_bytes=512)

        def f(*ls):
            g = dict(zip(keys, [l[0] for l in ls]))
            synced, _ = eng.gradient_sync(g, None)
            return tuple(synced[k][None] for k in keys)

        spec = P("data", None)
        outs[br] = smap(f, mesh8, (spec,) * len(keys),
                        (spec,) * len(keys))(
            *[jnp.asarray(grads[k]) for k in keys])
        compiled = next(iter(eng._sync_cache.values()))
        if br:
            assert "batched_allreduce" in compiled.stage_kinds()
    for i, k in enumerate(keys):
        np.testing.assert_array_equal(np.asarray(outs[True][i]),
                                      np.asarray(outs[False][i]))
        np.testing.assert_allclose(np.asarray(outs[False][i])[0],
                                   grads[k].mean(0), atol=1e-4)


def test_batched_ring_skips_dependent_reduces(rng):
    """A reduce consuming another reduce's output must not share its
    launch."""
    eng = make_engine("acis", batch_rings=True, bucket_bytes=0)

    def prog(x, y):
        a = acis.reduce(x, axis="data")
        b = acis.reduce(acis.map(lambda v: v * 0.5, a, name="h"),
                        axis="data")
        c = acis.reduce(y, axis="data")
        return a, b, c

    c = eng.compile(tracing.trace(prog),
                    in_avals=(AV((16,), jnp.float32),) * 2, axis_size=N)
    kinds = c.stage_kinds()
    # a and c batch together; b (dependent) stays its own launch — as a
    # plain ring, possibly with its feeding map fused in
    assert kinds.count("batched_allreduce") == 1
    assert kinds.count("allreduce") + kinds.count("map+allreduce") == 1
    c.source.validate()


def test_batched_stage_leads_its_dispatch_group():
    from repro.core.executor import _axis_groups

    class FakeStage:
        def __init__(self, kind, axis):
            self.kind, self.axis = kind, axis

    stages = [FakeStage("allreduce", "data"),
              FakeStage("batched_allreduce", "data"),
              FakeStage("map", "")]
    groups = _axis_groups(stages, (0, 1, 2))
    data_group = next(idxs for ax, idxs in groups if ax == "data")
    assert data_group == (1, 0)


# ---------------------------------------------------------------------------
# 3b. RS/AG bucketing
# ---------------------------------------------------------------------------

def _rs_prog(a, b, c):
    return (tracing.reduce_scatter(a, axis="data"),
            tracing.reduce_scatter(b, axis="data"),
            tracing.reduce_scatter(c, axis="data"))


def _ag_prog(a, b, c):
    return (tracing.all_gather(a, axis="data"),
            tracing.all_gather(b, axis="data"),
            tracing.all_gather(c, axis="data"))


@pytest.mark.parametrize("case", ["rs", "ag"])
def test_rs_ag_buckets_bitwise(mesh8, rng, case):
    prog = _rs_prog if case == "rs" else _ag_prog
    avals = (AV((16,), jnp.float32), AV((16, 3), jnp.float32),
             AV((8, 4), jnp.float32))
    xs = [rng.standard_normal((N,) + a.shape).astype(np.float32)
          for a in avals]
    outs, kinds = {}, {}
    for bb in (0, None):
        eng = make_engine("acis", bucket_bytes=bb)
        c = eng.compile(tracing.trace(prog), in_avals=avals, axis_size=N)
        kinds[bb] = c.stage_kinds()
        spec = P("data", None)
        outs[bb] = smap(
            lambda *vs: tuple(o[None] for o in c(*[v[0] for v in vs])),
            mesh8, (spec,) * 3, (spec,) * 3)(*[jnp.asarray(x) for x in xs])
    coll = "reduce_scatter" if case == "rs" else "allgather"
    assert kinds[0].count(coll) == 3
    assert kinds[None].count(coll) == 1     # 3 collectives → 1 bucket
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(outs[None][i]),
                                      np.asarray(outs[0][i]))


def test_rs_bucket_respects_scatter_axis_semantics(mesh8, rng):
    """Each rank's bucketized RS share equals the concat of its per-leaf
    shares — checked on every rank, not just rank 0."""
    avals = (AV((16,), jnp.float32), AV((24,), jnp.float32))
    xs = [rng.standard_normal((N,) + a.shape).astype(np.float32)
          for a in avals]
    eng = make_engine("acis")
    c = eng.compile(tracing.trace(lambda a, b: (
        tracing.reduce_scatter(a, axis="data"),
        tracing.reduce_scatter(b, axis="data"))),
        in_avals=avals, axis_size=N)
    spec = P("data", None)
    outs = smap(lambda *vs: tuple(o[None] for o in c(*[v[0] for v in vs])),
                mesh8, (spec,) * 2, (P("data", None),) * 2)(
        *[jnp.asarray(x) for x in xs])
    for x, o in zip(xs, outs):
        got = np.asarray(o)                  # [N, leaf_size/N]
        want = x.sum(0).reshape(N, -1)       # rank r holds chunk r
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rs_ag_pair_still_fuses_to_allreduce():
    """The RS∘AG → allreduce rebuild (RsAgPattern) must survive RS/AG
    bucketing: the pair is never split across a bucket boundary."""
    eng = make_engine("acis")

    def prog(a, b):
        return (tracing.all_gather(tracing.reduce_scatter(a, axis="data"),
                                   axis="data"),
                tracing.all_gather(tracing.reduce_scatter(b, axis="data"),
                                   axis="data"))

    c = eng.compile(tracing.trace(prog),
                    in_avals=(AV((16,), jnp.float32),) * 2, axis_size=N)
    kinds = c.stage_kinds()
    assert "reduce_scatter" not in kinds
    assert "allgather" not in kinds
    assert kinds.count("allreduce") == 2


def test_ragged_rs_stays_unbucketed():
    """Leading dim not divisible by the axis size: the per-leaf RS owns
    the ragged split; Coalesce must leave it alone."""
    eng = make_engine("acis")
    c = eng.compile(tracing.trace(lambda a, b: (
        tracing.reduce_scatter(a, axis="data"),
        tracing.reduce_scatter(b, axis="data"))),
        in_avals=(AV((13,), jnp.float32), AV((21,), jnp.float32)),
        axis_size=N)
    assert c.stage_kinds().count("reduce_scatter") == 2


# ---------------------------------------------------------------------------
# 4. cost model + simulator coverage for the new stage kind
# ---------------------------------------------------------------------------

def test_batched_allreduce_stage_time_equals_allreduce():
    p = netmodel.PAPER
    for m in (1 << 10, 1 << 20):
        assert netmodel.stage_time("batched_allreduce", N, m, p) == \
            netmodel.stage_time("allreduce", N, m, p)
    t = netmodel.stage_time_terms("batched_allreduce", N, 1 << 20)
    assert t == netmodel.stage_time_terms("allreduce", N, 1 << 20)


def test_batched_ring_amortization_helpers():
    p = netmodel.PAPER
    sizes = [1 << 16] * 6
    sep, bat = netmodel.batched_ring_times(N, sizes, p)
    assert bat < sep
    # the saving is exactly the (k-1) amortized hop walks
    hop_walk = 2 * (N - 1) * (p.fpga_link + p.port)
    np.testing.assert_allclose(sep - bat, (len(sizes) - 1) * hop_walk,
                               rtol=1e-9)
    for kind in ("reduce_scatter", "allgather"):
        sep, tot = netmodel.bucketed_collective_times(kind, N, sizes, p)
        assert tot < sep
    with pytest.raises(ValueError):
        netmodel.bucketed_collective_times("alltoall", N, sizes, p)


def test_batched_stage_analytic_vs_simulated(rng):
    """The simulator runs the batched kind through the same ring walk the
    analytic model charges: per-stage t_model is populated and the
    simulated time stays within the established envelope."""
    from repro.cgra.simulate import SwitchSim

    eng = make_engine("acis", batch_rings=True, bucket_bytes=0)
    c = eng.compile(tracing.trace(_batch_prog(ADD)),
                    in_avals=(AV((1 << 12,), jnp.float32),
                              AV((1 << 11,), jnp.float32),
                              AV((1 << 13,), jnp.float32)),
                    axis_size=N)
    assert "batched_allreduce" in c.stage_kinds()
    xs = [np.asarray(rng.standard_normal((N, 1 << s)), np.float32)
          for s in (12, 11, 13)]
    out, rep = SwitchSim({"data": N}).run(c, *xs)
    batched = [s for s in rep.stages if s.kind == "batched_allreduce"]
    assert batched and all(s.t_model for s in batched)
    for s in batched:
        assert 0.5 < s.deviation < 2.0
    # simulated numerics: plain per-leaf sums
    for x, o in zip(xs, out):
        np.testing.assert_allclose(np.asarray(o)[0], x.sum(0),
                                   rtol=1e-4, atol=1e-5)


def test_tune_space_covers_new_knobs():
    import importlib

    # repro.tune re-exports the search *function*; get the module
    search = importlib.import_module("repro.tune.search")
    assert "use_kernels" in search.TUNABLE_FIELDS
    assert "batch_rings" in search.TUNABLE_FIELDS
    assert set(search.DEFAULT_SPACE["use_kernels"]) == {False, True}
    assert set(search.DEFAULT_SPACE["batch_rings"]) == {False, True}
