"""repro.tune: stage traces, NetParams fitting, replay, plan search.

The whole loop runs against the dataplane simulator (simulated traces
use the same StageTrace format as wall-clock recordings), so record →
fit → replay → search is testable without hardware.
"""

import dataclasses
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.tune as tune
from repro.cgra.device import HostFallback
from repro.cgra.simulate import SwitchSim
from repro.core import ADD, make_engine, netmodel, tracing

search_mod = importlib.import_module("repro.tune.search")

AV = jax.ShapeDtypeStruct


def _sync_program(sizes, engine, axis_sizes):
    """Mean-sync over a flat list of f32 leaves (the execplan shape)."""
    n_total = 1
    for v in axis_sizes.values():
        n_total *= v

    def _mean(y):
        return y / n_total

    def sync(*gs):
        return tuple(
            tracing.map(_mean, tracing.reduce(g, axis="auto"),
                        name="mean", elementwise=True) for g in gs)

    prog = tracing.trace(sync, name=f"sync[{len(sizes)}]",
                         num_inputs=len(sizes))
    avals = tuple(AV((s,), jnp.float32) for s in sizes)
    return engine.compile(prog, in_avals=avals, axis_size=axis_sizes)


# ---------------------------------------------------------------------------
# StageTerms ≡ stage_time
# ---------------------------------------------------------------------------

class TestStageTerms:
    KINDS = ["allreduce", "reduce_scatter", "allgather", "alltoall",
             "bcast", "scan", "scan+allgather", "ef_allreduce",
             "allreduce+alltoall"]

    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("n", [1, 2, 4, 8])
    @pytest.mark.parametrize("m", [256, 1 << 16])
    def test_matches_stage_time(self, kind, n, m):
        p = netmodel.PAPER
        for schedule in ("", "latency", "bandwidth"):
            t_ref = netmodel.stage_time(kind, n, m, p, schedule=schedule)
            terms = netmodel.stage_time_terms(kind, n, m,
                                              schedule=schedule)
            assert terms is not None
            assert terms.time(p) == pytest.approx(t_ref, rel=1e-12)

    @pytest.mark.parametrize("kind", ["map", "allreduce",
                                      "reduce_scatter", "scan",
                                      "ef_allreduce",
                                      "allreduce+alltoall"])
    def test_matches_fallback_branch(self, kind):
        p = netmodel.PAPER
        hf = HostFallback("test")
        n, m = 4, 1 << 15
        t_ref = netmodel.stage_time(kind, n, m, p, placement=hf)
        terms = netmodel.stage_time_terms(kind, n, m, fallback=True)
        assert terms.time(p) == pytest.approx(t_ref, rel=1e-12)

    def test_codec_ratio_scales_wire(self):
        p = netmodel.PAPER
        t_ref = netmodel.stage_time("allreduce", 8, 1 << 20, p,
                                    codec_ratio=0.25)
        terms = netmodel.stage_time_terms("allreduce", 8, 1 << 20,
                                          codec_ratio=0.25)
        assert terms.time(p) == pytest.approx(t_ref, rel=1e-12)

    def test_plan_stage_terms_matches_plan_stage_time(self):
        """Over every stage of a real compiled sync (maps with
        placements, bucket packs, ring collectives), the decomposition
        reassembles to exactly what plan_stage_time prices."""
        eng = make_engine("acis")
        c = _sync_program([4096, 131072, 300, 65536], eng, {"data": 4})
        priced = 0
        for st in c.stages:
            got = netmodel.plan_stage_terms(st, c.topology)
            if got is None:
                continue
            tier, terms, placement = got
            net = c.topology.net(st.axis) if st.axis else netmodel.PAPER
            t_ref = netmodel.plan_stage_time(st, c.topology, netmodel.PAPER)
            assert terms.time(net, placement) == pytest.approx(
                t_ref, rel=1e-12)
            priced += 1
        assert priced > 0


# ---------------------------------------------------------------------------
# trace recording + JSONL round trip
# ---------------------------------------------------------------------------

class TestTrace:
    def _compiled(self, sizes=(4096, 131072, 65536), data=4):
        eng = make_engine("acis")
        c = _sync_program(list(sizes), eng, {"data": data})
        return c, list(sizes), data

    def test_sim_trace_shape(self):
        c, sizes, data = self._compiled()
        rng = np.random.default_rng(0)
        ins = [rng.standard_normal((data, s)).astype(np.float32)
               for s in sizes]
        outs, trace, report = tune.record_sim(
            c, SwitchSim(c.topology), *ins)
        assert len(trace.stages) == len(c.stages)
        assert trace.source == "sim"
        assert trace.t_end == report.t_end
        for ts in trace.stages:
            assert ts.t_end >= ts.t_start >= 0.0
            assert ts.kind == c.stages[ts.stage].kind

    def test_jsonl_round_trip(self, tmp_path):
        c, sizes, data = self._compiled()
        rng = np.random.default_rng(0)
        ins = [rng.standard_normal((data, s)).astype(np.float32)
               for s in sizes]
        _, trace, _ = tune.record_sim(c, SwitchSim(c.topology), *ins)
        path = tmp_path / "trace.jsonl"
        tune.save_jsonl(path, trace)
        back = tune.load_jsonl(path)
        assert len(back) == 1
        assert back[0].stages == trace.stages
        assert back[0].t_end == trace.t_end
        assert back[0].axes == trace.axes

    def test_loader_rejects_other_schema(self, tmp_path):
        path = tmp_path / "bad.jsonl"
        path.write_text(
            '{"record": "program", "schema": 999, "name": "x", '
            '"axes": {}, "t_end": 0.0, "source": "sim"}\n')
        with pytest.raises(ValueError, match="schema"):
            tune.load_jsonl(path)

    def test_loader_rejects_headerless_stage(self, tmp_path):
        path = tmp_path / "bad.jsonl"
        path.write_text('{"record": "stage", "stage": 0, "kind": "map"}\n')
        with pytest.raises(ValueError, match="header"):
            tune.load_jsonl(path)

    def test_instrumented_eager_map_program(self):
        """The executor's instrument hook: an axis-less (eager) program
        records one StageTrace per stage with real timestamps."""
        eng = make_engine("acis")

        def prog(x, y):
            a = tracing.map(lambda v: v * 2.0, x, name="double")
            return (tracing.map(jnp.add, a, y, name="add"),)

        c = eng.compile(prog, in_avals=(AV((1024,), jnp.float32),) * 2)
        out, trace = tune.record_instrumented(
            c, jnp.ones(1024), jnp.ones(1024))
        assert trace.source == "instrumented"
        assert len(trace.stages) == len(c.stages)
        assert trace.t_end > 0.0
        assert all(s.t_end >= s.t_start for s in trace.stages)
        np.testing.assert_allclose(np.asarray(out[0]), 3.0)


# ---------------------------------------------------------------------------
# replay: the two fixed points + determinism
# ---------------------------------------------------------------------------

class TestReplay:
    def _recorded(self, sizes=(4096, 131072, 65536), data=4):
        eng = make_engine("acis")
        c = _sync_program(list(sizes), eng, {"data": data})
        rng = np.random.default_rng(0)
        ins = [rng.standard_normal((data, s)).astype(np.float32)
               for s in sizes]
        _, trace, report = tune.record_sim(
            c, SwitchSim(c.topology), *ins)
        return c, trace, report

    def test_empty_trace_is_program_time(self):
        c, _, _ = self._recorded()
        r = tune.replay(c.plan, None, c.topology)
        assert r.matched == 0
        assert r.t_end == pytest.approx(
            netmodel.program_time(c.plan, c.topology), rel=1e-12)

    def test_self_replay_reproduces_recording(self):
        """Acceptance: self-replay fidelity within 5% (here: exact, the
        replayer's wave merge is the simulator's)."""
        c, trace, report = self._recorded()
        r = tune.replay(c.plan, trace, c.topology)
        assert r.match_fraction == 1.0
        assert abs(r.t_end - report.t_end) <= 0.05 * report.t_end

    def test_deterministic(self):
        c, trace, _ = self._recorded()
        r1 = tune.replay(c.plan, trace, c.topology)
        r2 = tune.replay(c.plan, trace, c.topology)
        assert r1.t_end == r2.t_end
        assert r1.stages == r2.stages

    def test_serial_mode_sums_chains(self):
        c, trace, _ = self._recorded()
        r_ov = tune.replay(c.plan, trace, c.topology, overlapped=True)
        r_ser = tune.replay(c.plan, trace, c.topology, overlapped=False)
        assert r_ser.t_end >= r_ov.t_end

    def test_mismatched_stages_fall_back_to_model(self):
        """A candidate plan the recording doesn't cover scores on the
        analytic model — replay stays defined across plan changes."""
        c, trace, _ = self._recorded()
        eng = make_engine("acis", bucket_bytes=0)
        c2 = _sync_program([4096, 131072, 65536], eng, {"data": 4})
        r = tune.replay(c2.plan, trace, c2.topology)
        assert r.modeled > 0
        assert r.t_end > 0.0


# ---------------------------------------------------------------------------
# fit: NetParams recovery from simulated traces
# ---------------------------------------------------------------------------

class TestFit:
    def _perturbed_samples(self, *, bw_scale=0.5, link_scale=2.0):
        """Per-leaf sync (diverse payload sizes → hop and 1/bw columns
        separate) simulated under perturbed ici link parameters."""
        sizes = [4096, 65536, 131072, 524288, 8192, 262144]
        eng = make_engine("acis", bucket_bytes=0)
        c = _sync_program(sizes, eng, {"data": 4})
        sim = SwitchSim(c.topology)
        true = dataclasses.replace(
            sim.nets["data"], bw=sim.nets["data"].bw * bw_scale,
            fpga_link=sim.nets["data"].fpga_link * link_scale)
        sim.nets["data"] = true
        rng = np.random.default_rng(0)
        ins = [rng.standard_normal((4, s)).astype(np.float32)
               for s in sizes]
        _, trace, _ = tune.record_sim(c, sim, *ins)
        return [(c.plan, c.topology, trace)], true

    def test_recovers_perturbed_link_params(self):
        """Acceptance: fit.py recovers the simulator's NetParams."""
        samples, true = self._perturbed_samples()
        fit = tune.fit_net_params(samples, tiers=("ici",))
        got = fit.tiers["ici"]
        assert got.bw == pytest.approx(true.bw, rel=0.05)
        assert got.fpga_link == pytest.approx(true.fpga_link, rel=0.05)
        assert fit.residual < 1e-6

    def test_unobserved_tier_drops_to_prior(self):
        """Single-axis traces cannot identify the dci columns: the fit
        drops them (fit_tier_overlap's drop-and-resolve) and keeps the
        prior values rather than inventing numbers."""
        samples, _ = self._perturbed_samples()
        fit = tune.fit_net_params(samples, tiers=("ici", "dci"))
        assert "dci.hop" in fit.dropped
        assert "dci.invbw" in fit.dropped
        assert fit.tiers["dci"].bw == netmodel.TIERS["dci"].bw

    def test_collinear_columns_drop_and_resolve(self):
        """Every recorded stage carrying the same payload makes hop and
        1/bw collinear — one column must fall back to its prior and the
        other still solve, exactly like fit_tier_overlap's degenerate
        handling."""
        sizes = [65536, 65536, 65536]
        eng = make_engine("acis", bucket_bytes=0)
        c = _sync_program(sizes, eng, {"data": 4})
        rng = np.random.default_rng(0)
        ins = [rng.standard_normal((4, s)).astype(np.float32)
               for s in sizes]
        _, trace, _ = tune.record_sim(c, SwitchSim(c.topology), *ins)
        fit = tune.fit_net_params([(c.plan, c.topology, trace)],
                                  tiers=("ici",))
        assert "ici.hop" in fit.dropped or "ici.invbw" in fit.dropped
        # the solved system still reproduces the recorded stage times
        assert fit.residual < 1e-6

    def test_fit_traces_overlap_special_case(self):
        """fit_traces = link fit + fit_tier_overlap under the fitted
        params; on unperturbed single-axis traces both halves stay at
        their calibrated values."""
        sizes = [4096, 131072, 65536]
        eng = make_engine("acis")
        c = _sync_program(sizes, eng, {"data": 4})
        rng = np.random.default_rng(0)
        ins = [rng.standard_normal((4, s)).astype(np.float32)
               for s in sizes]
        _, trace, _ = tune.record_sim(c, SwitchSim(c.topology), *ins)
        fit = tune.fit_traces([(c.plan, c.topology, trace)],
                              tiers=("ici",))
        assert set(fit.overlap) >= {"ici", "dci", "local"}
        prior = netmodel.TIERS["ici"]
        assert fit.tiers["ici"].bw == pytest.approx(prior.bw, rel=0.05)

    def test_fitted_params_flow_into_replay(self):
        """Replaying under a fit prices model stages with the fitted
        link parameters: halved bandwidth → longer modeled time."""
        samples, true = self._perturbed_samples()
        fit = tune.fit_net_params(samples, tiers=("ici",))
        plan, topo, _ = samples[0]
        r_prior = tune.replay(plan, None, topo)
        r_fit = tune.replay(plan, None, topo, fit=fit)
        assert r_fit.t_end > r_prior.t_end


# ---------------------------------------------------------------------------
# search + tuning DB
# ---------------------------------------------------------------------------

def _tail_sizes(n=64):
    rng = np.random.default_rng(7)
    return [int(rng.integers(1 << 8, 1 << 13)) for _ in range(n)]


class TestSearch:
    def _build(self, sizes, axis_sizes):
        def build(cfg):
            eng = make_engine("acis")
            eng.config = cfg
            return _sync_program(sizes, eng, axis_sizes)
        return build

    def test_search_beats_default_on_ragged_tail(self):
        """Acceptance: the searched config's analytic program_time beats
        the default bucket_bytes config on the 64-leaf ragged sync."""
        base = make_engine("acis").config
        build = self._build(_tail_sizes(), {"data": 8})
        res = tune.search(build, base=base)
        assert res.overrides, "search found nothing to change"
        assert res.score < res.default_score
        tuned = build(dataclasses.replace(base, **res.overrides))
        default = build(base)
        assert tuned.program_time() < default.program_time()

    def test_search_is_deterministic(self):
        base = make_engine("acis").config
        build = self._build(_tail_sizes(16), {"data": 4})
        r1 = tune.search(build, base=base)
        r2 = tune.search(build, base=base)
        assert r1.overrides == r2.overrides
        assert r1.score == r2.score

    def test_tunedb_round_trip(self, tmp_path):
        db = tune.TuneDB(str(tmp_path / "db.json"))
        db.store("k1", {"bucket_bytes": 0}, score=1.0)
        assert db.lookup("k1")["overrides"] == {"bucket_bytes": 0}
        db2 = tune.TuneDB(str(tmp_path / "db.json"))
        assert db2.lookup("k1")["overrides"] == {"bucket_bytes": 0}
        assert db2.lookup("nope") is None

    def test_tunedb_ignores_foreign_schema(self, tmp_path):
        path = tmp_path / "db.json"
        path.write_text('{"schema": 999, "entries": {"k": {}}}')
        assert tune.TuneDB(str(path)).lookup("k") is None

    def test_autotune_hits_db_without_research(self, tmp_path):
        """Acceptance: the second engine compile of the same (pytree,
        topology) with autotune on hits the DB — no second search."""
        db = str(tmp_path / "tune.json")
        sizes = _tail_sizes(16)
        avals = tuple(AV((s,), jnp.float32) for s in sizes)
        treedef = jax.tree_util.tree_structure([0] * len(sizes))

        n0 = search_mod.SEARCHES_RUN
        e1 = make_engine("acis", autotune=True, tune_db=db)
        c1 = e1._sync_program(treedef, avals, None,
                              axis_sizes={"data": 8})
        assert search_mod.SEARCHES_RUN == n0 + 1
        e2 = make_engine("acis", autotune=True, tune_db=db)
        c2 = e2._sync_program(treedef, avals, None,
                              axis_sizes={"data": 8})
        assert search_mod.SEARCHES_RUN == n0 + 1, "DB hit re-searched"
        assert [s.kind for s in c2.stages] == [s.kind for s in c1.stages]
        # the tuned program is what the default would NOT have built
        e3 = make_engine("acis")
        c3 = e3._sync_program(treedef, avals, None,
                              axis_sizes={"data": 8})
        assert c1.program_time() < c3.program_time()

    def test_autotuned_sync_matches_default_numerics(self, mesh8):
        """gradient_sync under autotune returns the same mean as the
        untuned path — tuning changes the plan, not the math."""
        import tempfile

        db = tempfile.mktemp(suffix=".json")
        sizes = _tail_sizes(8)
        rng = np.random.default_rng(3)
        grads = [jnp.asarray(rng.standard_normal((8, s))
                             .astype(np.float32)) for s in sizes]

        def run(engine):
            def step(*gs):
                synced, _ = engine.gradient_sync(
                    [g[0] for g in gs], None)
                return tuple(s[None] for s in synced)
            from jax.sharding import PartitionSpec as P
            fn = jax.jit(jax.shard_map(
                step, mesh=mesh8, in_specs=(P("data"),) * len(sizes),
                out_specs=(P("data"),) * len(sizes), check_vma=False))
            return fn(*grads)

        want = run(make_engine("acis"))
        got = run(make_engine("acis", autotune=True, tune_db=db))
        for w, g in zip(want, got):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-5)

    def test_autotune_compile_star_args_program(self, tmp_path):
        """engine.compile with autotune on must handle ``*args``-signature
        programs: the arity comes from in_avals (trace() alone cannot
        infer it), and the program is traced once, not per candidate."""
        eng = make_engine("acis", autotune=True,
                          tune_db=str(tmp_path / "tune.json"))
        sizes = _tail_sizes(6)
        c = eng.compile(
            lambda *vs: tuple(tracing.reduce(v, ADD, axis="data")
                              for v in vs),
            in_avals=tuple(AV((s,), jnp.float32) for s in sizes),
            axis_size={"data": 8})
        assert len(c.plan.stages) > 0
        xs = [np.ones((8, s), np.float32) for s in sizes]
        outs, _ = SwitchSim(c.topology).run(c, *xs)
        for s, o in zip(sizes, outs):
            np.testing.assert_allclose(np.asarray(o)[0],
                                       np.full((s,), 8.0), rtol=1e-6)

    def test_sync_cache_keys_on_config_fields(self):
        """The cache-key fix: the same engine re-pointed at a config
        differing only in tuned fields must not return the stale
        program (pre-fix, the key ignored every config field)."""
        sizes = [4096, 131072, 65536, 8192]
        avals = tuple(AV((s,), jnp.float32) for s in sizes)
        treedef = jax.tree_util.tree_structure([0] * len(sizes))
        eng = make_engine("acis")
        c1 = eng._sync_program(treedef, avals, None,
                               axis_sizes={"data": 4})
        eng.config = dataclasses.replace(eng.config, bucket_bytes=0)
        c2 = eng._sync_program(treedef, avals, None,
                               axis_sizes={"data": 4})
        assert len(c2.stages) != len(c1.stages)

    def test_arena_cache_keys_on_program(self):
        """Arenas are keyed by the compiled program: per-leaf and
        bucketized configs over one pytree get distinct (here: absent
        vs present) arena sets."""
        sizes = _tail_sizes(16)
        grads = [np.zeros(s, np.float32) for s in sizes]
        e1 = make_engine("acis")
        a1 = e1.init_arenas(grads, axis_sizes={"data": 4})
        e2 = make_engine("acis", bucket_bytes=0)
        a2 = e2.init_arenas(grads, axis_sizes={"data": 4})
        assert a1 is not None
        assert a2 is None  # per-leaf sync has no bucket packs


# ---------------------------------------------------------------------------
# explain(trace=...)
# ---------------------------------------------------------------------------

class TestExplainTrace:
    def test_measured_vs_model_columns(self):
        eng = make_engine("acis")
        sizes = [4096, 131072, 65536]
        c = _sync_program(sizes, eng, {"data": 4})
        rng = np.random.default_rng(0)
        ins = [rng.standard_normal((4, s)).astype(np.float32)
               for s in sizes]
        _, trace, _ = tune.record_sim(c, SwitchSim(c.topology), *ins)
        text = c.explain(trace)
        assert "meas_us" in text
        assert "model_us" in text
        assert "mispredict ratio" in text
        # the plain table still renders without a trace
        assert "meas_us" not in c.explain()
