"""Compiled serving data path: TP decode through engine.compile.

Covers the serve/collectives layer: dense tensor-parallel decode and the
MoE expert all-to-all dispatch/combine as compiled switch programs
(numerics vs the plain path, incl. under obs.recording()), the shared
SwitchProgramCache across engine replicas, SLO-aware admission, and the
deque/batched-reset engine mechanics.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, obs
from repro.core.api import CollectiveConfig
from repro.models import Model
from repro.serve.collectives import (PROGRAM_CACHE, ServeCollectives,
                                     SwitchProgramCache)
from repro.serve.engine import Request, ServeEngine, SLOPolicy

TP = 2


def _fixture(arch, key=0, slots=4, seq=48):
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(key))
    cache = model.init_cache(slots, seq)
    return cfg, model, params, cache


def _tree_allclose(a, b, tol):
    fa = sorted(jax.tree_util.tree_flatten_with_path(a)[0],
                key=lambda kv: str(kv[0]))
    fb = sorted(jax.tree_util.tree_flatten_with_path(b)[0],
                key=lambda kv: str(kv[0]))
    assert len(fa) == len(fb)
    for (ka, la), (kb, lb) in zip(fa, fb):
        d = np.abs(np.asarray(la, np.float32)
                   - np.asarray(lb, np.float32)).max()
        assert d <= tol, (jax.tree_util.keystr(ka), float(d))


@pytest.fixture(scope="module")
def dense():
    return _fixture("acis-100m")


@pytest.fixture(scope="module")
def moe():
    return _fixture("qwen2-moe-a2-7b", key=1)


# ---------------------------------------------------------------------------
# numerics: compiled TP decode vs the plain (unsharded) path
# ---------------------------------------------------------------------------

def test_dense_compiled_decode_matches_plain(dense):
    cfg, model, params, cache = dense
    sc = ServeCollectives(cfg, TP, cache=SwitchProgramCache())
    dec_c = sc.decode_fn(params, cache, mode="compiled", donate=False)
    dec_d = sc.decode_fn(params, cache, mode="direct", donate=False)
    plain = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))

    tok = jnp.array([3, 5, 7, 9], jnp.int32)
    cc, cd, cp = cache, cache, cache
    for step in range(4):
        i = jnp.full(4, step, jnp.int32)
        lc, cc = dec_c(params, tok, cc, i)
        ld, cd = dec_d(params, tok, cd, i)
        lp, cp = plain(params, tok, cp, i)
        # compiled vs uncompiled-acis: identical rank-local math, bit-exact
        assert (np.asarray(lc) == np.asarray(ld)).all()
        # vs the unsharded path: TP sums bf16 partials -> ulp-level slack
        np.testing.assert_allclose(np.asarray(lc), np.asarray(lp),
                                   atol=3e-2, rtol=3e-2)
        tok = jnp.argmax(lc, -1).astype(jnp.int32)
    _tree_allclose(cc, cd, 0.0)
    _tree_allclose(cc, cp, 3e-2)


def test_moe_compiled_dispatch_combine_matches_plain(moe):
    """The MoE expert all-to-all (dispatch + Type-4 fused combine with the
    shared-expert all-reduce) through engine.compile vs plain moe.py."""
    cfg, model, params, cache = moe
    assert cfg.moe.n_shared, "smoke config must exercise the fused combine"
    sc = ServeCollectives(cfg, TP, cache=SwitchProgramCache())
    dec_c = sc.decode_fn(params, cache, mode="compiled", donate=False)
    plain = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))

    # the decode tick compiles an alltoall and a fused allreduce+alltoall
    kinds = [name for name, _, _ in sc.decode_programs(4)]
    assert "serve_moe_alltoall" in kinds
    assert "serve_moe_combine" in kinds

    tok = jnp.array([11, 2, 250, 77], jnp.int32)
    cc, cp = cache, cache
    for step in range(3):
        i = jnp.full(4, step, jnp.int32)
        lc, cc = dec_c(params, tok, cc, i)
        lp, cp = plain(params, tok, cp, i)
        np.testing.assert_allclose(np.asarray(lc), np.asarray(lp),
                                   atol=5e-2, rtol=5e-2)
        tok = jnp.argmax(lp, -1).astype(jnp.int32)
    _tree_allclose(cc, cp, 5e-2)


def test_moe_compiled_path_under_recording(moe):
    """Same numerics with obs recording on, and the serve counters land."""
    cfg, model, params, cache = moe
    plain = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
    tok = jnp.array([4, 8, 15, 16], jnp.int32)
    i = jnp.zeros(4, jnp.int32)
    with obs.recording() as rec:
        sc = ServeCollectives(cfg, TP, cache=SwitchProgramCache())
        dec = sc.decode_fn(params, cache, mode="compiled", donate=False)
        lc, _ = dec(params, tok, cache, i)
    lp, _ = plain(params, tok, cache, i)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(lp),
                               atol=5e-2, rtol=5e-2)
    assert rec.counter("serve.program_cache_miss") >= 3
    assert rec.counter("compile.programs") >= 3


def test_fused_combine_stage_is_type4(moe):
    cfg, _, _, _ = moe
    sc = ServeCollectives(cfg, TP, cache=SwitchProgramCache())
    by_name = {name: prog for name, prog, _ in sc.decode_programs(4)}
    assert "allreduce+alltoall" in by_name["serve_moe_combine"].explain()
    # analytic costs are finite and ordered: a prefill pass moves more
    # bytes than a decode tick
    assert 0 < sc.decode_comm_time(4) < sc.prefill_comm_time(4, 16)


# ---------------------------------------------------------------------------
# the engine on the compiled transport
# ---------------------------------------------------------------------------

def test_engine_on_compiled_collectives_matches_direct(dense, rng):
    """Full continuous-batching run over the compiled transport: identical
    completions to the uncompiled (direct-ring) transport, slots recycled."""
    cfg, model, params, _ = dense
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 3 + i).astype(np.int32),
                    max_new_tokens=4 + (i % 3))
            for i in range(5)]

    def run(mode):
        sc = ServeCollectives(cfg, TP, cache=SwitchProgramCache())
        eng = ServeEngine(model, params, slots=2, max_seq=48, collectives=sc)
        eng._decode = sc.decode_fn(params, eng.cache, mode=mode)
        for r in reqs:
            eng.submit(Request(**{f.name: getattr(r, f.name)
                                  for f in r.__dataclass_fields__.values()}))
        return eng.run_to_completion()

    done_c = run("compiled")
    done_d = run("direct")
    assert len(done_c) == len(done_d) == 5
    for a, b in zip(done_c, done_d):
        assert (a.rid, a.tokens) == (b.rid, b.tokens)


def test_shared_program_cache_across_replicas(dense):
    """Two ServeEngine replicas sharing one SwitchProgramCache: the second
    replica's decode build is all cache hits — no recompiles, asserted via
    the obs counters."""
    cfg, model, params, _ = dense
    shared = SwitchProgramCache()
    prompt = np.arange(4, dtype=np.int32)

    def replica():
        sc = ServeCollectives(cfg, TP, cache=shared)
        eng = ServeEngine(model, params, slots=2, max_seq=48, collectives=sc)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
        return eng.run_to_completion()

    with obs.recording() as rec:
        done1 = replica()
        misses_after_first = rec.counter("serve.program_cache_miss")
        compiles_after_first = rec.counter("compile.programs")
        assert misses_after_first >= 1
        done2 = replica()
    assert done1[0].tokens == done2[0].tokens
    # second replica: hits only — miss and compile counters unchanged
    assert rec.counter("serve.program_cache_miss") == misses_after_first
    assert rec.counter("compile.programs") == compiles_after_first
    assert rec.counter("serve.program_cache_hit") > 0
    assert shared.stats()["hits"] > 0
    assert shared.stats()["misses"] == misses_after_first


def test_default_cache_is_process_wide(dense):
    cfg, _, _, _ = dense
    sc = ServeCollectives(cfg, TP)
    assert sc.cache is PROGRAM_CACHE


# ---------------------------------------------------------------------------
# SLO-aware admission
# ---------------------------------------------------------------------------

def test_slo_admission_rejects_impossible_deadline(dense):
    cfg, model, params, _ = dense
    rec = obs.Recorder()
    eng = ServeEngine(model, params, slots=2, max_seq=64,
                      recorder=rec, admission=SLOPolicy())
    # warm the tick-time estimate so the policy has a basis
    eng.submit(Request(rid=0, prompt=np.arange(3, dtype=np.int32),
                       max_new_tokens=2))
    eng.run_to_completion()
    eng.submit(Request(rid=1, prompt=np.arange(5, dtype=np.int32),
                       max_new_tokens=8, deadline_s=1e-9))
    eng.submit(Request(rid=2, prompt=np.arange(3, dtype=np.int32),
                       max_new_tokens=2, deadline_s=60.0))
    done = eng.run_to_completion()
    assert [r.rid for r in eng.rejected] == [1]
    assert sorted(c.rid for c in done) == [0, 2]
    assert rec.counter("serve.slo_rejected") == 1
    assert rec.gauges.get("serve.deadline_headroom_s", 0) > 0


def test_slo_admission_defers_on_prefill_pressure(dense):
    cfg, model, params, _ = dense
    rec = obs.Recorder()
    eng = ServeEngine(model, params, slots=3, max_seq=64, recorder=rec,
                      admission=SLOPolicy(max_concurrent_prefills=1))
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=2))
    done = eng.run_to_completion()
    # everything still completes; admission was staggered, not starved
    assert sorted(c.rid for c in done) == [0, 1, 2]
    assert rec.counter("serve.admit_deferred") >= 1


def test_tick_time_estimate_prefers_measured(dense):
    cfg, model, params, _ = dense
    sc = ServeCollectives(cfg, TP, cache=SwitchProgramCache())
    eng = ServeEngine(model, params, slots=2, max_seq=48, collectives=sc)
    analytic = eng.tick_time_estimate()
    assert analytic == sc.decode_comm_time(2) > 0
    eng.submit(Request(rid=0, prompt=np.arange(3, dtype=np.int32),
                       max_new_tokens=2))
    eng.run_to_completion()
    assert eng.tick_time_estimate() == float(np.median(eng._tick_times))


# ---------------------------------------------------------------------------
# engine mechanics: deque queue, queue-depth gauge, batched slot reset
# ---------------------------------------------------------------------------

def test_queue_is_deque_with_depth_gauge(dense):
    import collections
    cfg, model, params, _ = dense
    rec = obs.Recorder()
    eng = ServeEngine(model, params, slots=1, max_seq=64, recorder=rec)
    assert isinstance(eng.queue, collections.deque)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.arange(2, dtype=np.int32),
                           max_new_tokens=1))
    eng.step()
    # gauged before admission: all three were queued, one took the slot
    assert rec.gauges["serve.queue_depth"] == 3
    assert rec.counter("serve.host_sync") == 1
    assert rec.gauges["serve.decode_p50_s"] > 0
    assert rec.gauges["serve.decode_p99_s"] > 0


def test_batched_slot_reset_single_traversal(dense, monkeypatch):
    """All admits in a tick share ONE cache tree traversal."""
    cfg, model, params, _ = dense
    eng = ServeEngine(model, params, slots=4, max_seq=64)
    calls = []
    orig = ServeEngine._reset_slot_caches

    def spy(self, slot_ids):
        calls.append(list(slot_ids))
        return orig(self, slot_ids)

    monkeypatch.setattr(ServeEngine, "_reset_slot_caches", spy)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=np.arange(2, dtype=np.int32),
                           max_new_tokens=1))
    eng.step()
    assert calls == [[0, 1, 2, 3]]


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_rejects_indivisible_tp(dense):
    cfg, _, _, _ = dense
    with pytest.raises(ValueError, match="n_kv_heads"):
        ServeCollectives(cfg, 4)   # smoke acis-100m has n_kv_heads=2


def test_rejects_unsupported_family():
    cfg = configs.get_smoke("rwkv6-1.6b")
    with pytest.raises(NotImplementedError):
        ServeCollectives(cfg, 2)


def test_rejects_xla_backend(dense):
    cfg, _, _, _ = dense
    with pytest.raises(ValueError, match="acis"):
        ServeCollectives(cfg, 2, config=CollectiveConfig(backend="xla"))


def test_slo_expired_deadline_rejects_even_under_prefill_cap():
    """Pre-PR ordering left an expired request parked at the queue head,
    re-deferred every tick by the prefill cap; the deadline check now
    runs first."""
    class StubEngine:
        slots = 2
        collectives = None

        def tick_time_estimate(self):
            return None

    pol = SLOPolicy(max_concurrent_prefills=1)
    expired = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                      max_new_tokens=4, deadline_s=0.5,
                      t_submit=time.monotonic() - 1.0)    # waited 1s > 0.5s
    assert pol.decide(expired, StubEngine(), n_prefilling=1) == "reject"
    # without a deadline the cap still defers
    fresh = Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=4, t_submit=time.monotonic())
    assert pol.decide(fresh, StubEngine(), n_prefilling=1) == "defer"


def test_slo_membership_inflates_estimate():
    """Masked ranks degrade the fabric: the same deadline that admits on
    a healthy membership rejects once enough ranks are dead."""
    from repro.elastic import Membership

    class StubEngine:
        slots = 2
        collectives = None

        def tick_time_estimate(self):
            return 1e-3

    req = Request(rid=0, prompt=np.arange(10, dtype=np.int32),
                  max_new_tokens=10, deadline_s=0.04,
                  t_submit=time.monotonic())
    healthy = SLOPolicy(membership=Membership.all_alive(4))
    assert healthy.decide(req, StubEngine(), 0) == "admit"   # est ~0.02s
    degraded = SLOPolicy(membership=Membership.all_alive(4).drop(1, 2, 3))
    assert degraded.decide(req, StubEngine(), 0) == "reject"  # est ~0.08s


def test_slo_dead_fabric_rejects_deadlines_end_to_end(dense):
    """All ranks masked => infinite tick estimate: deadline-carrying
    requests reject at admission instead of hanging mid-decode, while
    best-effort traffic still completes."""
    from repro.elastic import Membership

    cfg, model, params, _ = dense
    rec = obs.Recorder()
    eng = ServeEngine(model, params, slots=2, max_seq=64, recorder=rec,
                      admission=SLOPolicy(
                          membership=Membership.all_alive(2).drop(0, 1)))
    eng.submit(Request(rid=0, prompt=np.arange(3, dtype=np.int32),
                       max_new_tokens=2))
    eng.run_to_completion()                     # warm the tick estimate
    eng.submit(Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=2, deadline_s=60.0))
    eng.submit(Request(rid=2, prompt=np.arange(3, dtype=np.int32),
                       max_new_tokens=2))       # best-effort: unaffected
    done = eng.run_to_completion()
    assert [r.rid for r in eng.rejected] == [1]
    assert sorted(c.rid for c in done) == [0, 2]
    assert rec.counter("serve.slo_rejected") == 1
