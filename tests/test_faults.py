"""Failure injection in the simulator + drift-watchdog attribution.

SwitchSim's :class:`FaultPlan` injects endpoint-dead ranks, stragglers
and ×k degraded links without changing any buffer shape — a masked
program keeps producing correct live-rank numerics while the timing
report degrades linearly, never a cliff.  The drift watchdog then reads
those reports and attributes the divergence: a sick rank or degraded
link is flagged *locally* (mask it / degrade the tier) and must NOT
trigger a model refit.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.cgra.simulate import FaultPlan, SwitchSim
from repro.core import make_engine, tracing
from repro.obs import metrics as obs
from repro.obs.drift import DriftWatchdog

AV = jax.ShapeDtypeStruct

N = 16


@pytest.fixture(scope="module")
def masked16():
    eng = make_engine("acis", inner_axis="data")

    def prog(x, alive):
        return tracing.masked_reduce(x, alive, axis="auto")

    return eng.compile(prog, axis_size=N,
                       in_avals=(AV((1 << 12,), jnp.float32),
                                 AV((), jnp.float32)))


@pytest.fixture(scope="module")
def x16():
    return np.random.default_rng(0).standard_normal(
        (N, 1 << 12)).astype(np.float32)


def _run(compiled, x, dead=(), timeout=0.0, **faults):
    alive = np.ones((N,), np.float32)
    alive[list(dead)] = 0.0
    plan = FaultPlan(dead=frozenset(dead), detect_timeout_s=timeout,
                     **faults)
    sim = SwitchSim(compiled.topology,
                    faults=plan if plan else None)
    return sim.run(compiled, x, alive)


# ---------------------------------------------------------------------------
# FaultPlan validation
# ---------------------------------------------------------------------------

def test_fault_plan_validation(masked16):
    with pytest.raises(ValueError, match="k must be"):
        FaultPlan(degraded_links=(("data", 0.5),))
    with pytest.raises(ValueError, match="out of range"):
        SwitchSim(masked16.topology,
                  faults=FaultPlan(dead=frozenset({N})))
    with pytest.raises(ValueError, match="unknown axis"):
        SwitchSim(masked16.topology,
                  faults=FaultPlan(degraded_links=(("ether", 2.0),)))
    assert not FaultPlan()          # empty plan is falsy (no-fault path)


# ---------------------------------------------------------------------------
# dead ranks: numerics stay correct, timing degrades linearly
# ---------------------------------------------------------------------------

def test_dead_ranks_keep_live_numerics_and_degrade_linearly(masked16, x16):
    (_, _), rep0 = _run(masked16, x16)
    t0 = rep0.t_end
    timeout = 0.25 * t0
    ts = []
    for k in (0, 1, 2, 4):
        dead = tuple(range(k))
        (v, cnt), rep = _run(masked16, x16, dead=dead, timeout=timeout)
        live = np.ones(N, bool)
        live[list(dead)] = False
        np.testing.assert_allclose(np.asarray(v)[N - 1],
                                   x16[live].mean(0), atol=1e-5)
        assert np.asarray(cnt)[N - 1] == N - k
        assert len(rep.rank_t_end) == N
        ts.append(rep.t_end)
    for a, b in zip(ts, ts[1:]):
        assert b >= a * 0.999, (ts,)          # monotone in failures
        assert b <= 2.0 * a, ("cliff", ts)    # linear-ish, never a cliff


def test_dead_ranks_counter(masked16, x16):
    with obs.recording() as rec:
        _run(masked16, x16, dead=(2, 9), timeout=1e-6)
    assert rec.counter("sim.dead_ranks") == 2


def test_straggler_and_degraded_link_slow_the_run(masked16, x16):
    (_, _), rep0 = _run(masked16, x16)
    t0 = rep0.t_end
    _, rs = _run(masked16, x16, straggler_s=((3, t0),))
    assert rs.t_end > t0
    assert rs.rank_t_end[3] >= max(                 # the straggler is last
        t for r, t in enumerate(rs.rank_t_end) if r != 3)
    _, rd = _run(masked16, x16, degraded_links=(("data", 2.0),))
    assert rd.t_end > t0


# ---------------------------------------------------------------------------
# drift watchdog attribution over fault reports
# ---------------------------------------------------------------------------

def _hier_sync():
    eng = make_engine("acis_hierarchical", inner_axis="data",
                      outer_axis="pod")

    def prog(x):
        return tracing.reduce(x, axis="auto")

    compiled = eng.compile(prog, axis_size={"data": 4, "pod": 2},
                           in_avals=(AV((1 << 12,), jnp.float32),))
    grid = SwitchSim(compiled.topology).grid        # e.g. (4, 2)
    x = np.arange(8 * (1 << 12), dtype=np.float32).reshape(
        grid + (1 << 12,))
    return compiled, x


def test_drift_quiet_on_faithful_replay():
    compiled, x = _hier_sync()
    wd = DriftWatchdog()
    sim = SwitchSim(compiled.topology)
    for _ in range(2):
        _, rep = sim.run(compiled, x)
        wd.observe_report(rep)
    assert not wd.alerts() and not wd.rank_alerts()
    assert wd.classify().verdict == "quiet"
    assert not wd.refit_recommended()


def test_drift_attributes_dead_rank_locally(masked16, x16):
    """A dead rank must read as *that rank is sick* — mask it — not as a
    stale network model begging for a refit."""
    wd = DriftWatchdog()
    for _ in range(2):
        _, rep = _run(masked16, x16, dead=(5,), timeout=1e-5)
        wd.observe_report(rep)
    verdict = wd.classify()
    assert verdict.verdict == "rank" and 5 in verdict.ranks
    assert verdict.local
    with obs.recording() as rec:
        assert not wd.refit_recommended()
    assert rec.counter("drift.rank_local") >= 1


def test_drift_attributes_degraded_link_locally():
    """A ×4 link on one tier drifts that axis's stage pools while the
    other tier stays quiet → link verdict, no refit."""
    compiled, x = _hier_sync()
    wd = DriftWatchdog()
    sim = SwitchSim(compiled.topology,
                    faults=FaultPlan(degraded_links=(("data", 4.0),)))
    for _ in range(2):
        _, rep = sim.run(compiled, x)
        wd.observe_report(rep)
    verdict = wd.classify()
    assert verdict.verdict == "link", verdict
    assert "data" in verdict.axes and "pod" not in verdict.axes
    with obs.recording() as rec:
        assert not wd.refit_recommended()
    assert rec.counter("drift.link_local") >= 1
