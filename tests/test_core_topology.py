"""Hierarchical multi-pod schedules + straggler masking + engine facade."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import make_engine, topology
from repro.core.wire import BF16


def smap(fn, mesh, in_specs, out_specs):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


def test_hierarchical_allreduce_matches_flat(mesh24, rng):
    # mesh24: pod=2, data=4
    x = rng.standard_normal((8, 33)).astype(np.float32)

    def f(xl):
        return topology.hierarchical_all_reduce(
            xl[0, 0], inner_axis="data", outer_axis="pod", mean=True)[None, None]

    out = np.asarray(smap(f, mesh24, P("pod", "data", None),
                          P("pod", "data", None))(
        jnp.asarray(x.reshape(2, 4, 33))))
    want = x.mean(axis=0)
    for p in range(2):
        for d in range(4):
            np.testing.assert_allclose(out[p, d], want, rtol=1e-4, atol=1e-4)


def test_hierarchical_with_bf16_interpod_wire(mesh24, rng):
    x = (rng.standard_normal((8, 64)) * 0.1).astype(np.float32)

    def f(xl):
        return topology.hierarchical_all_reduce(
            xl[0, 0], inner_axis="data", outer_axis="pod",
            outer_codec=BF16, mean=True)[None, None]

    out = np.asarray(smap(f, mesh24, P("pod", "data", None),
                          P("pod", "data", None))(
        jnp.asarray(x.reshape(2, 4, 64))))
    np.testing.assert_allclose(out[0, 0], x.mean(axis=0), atol=5e-3)


def test_masked_all_reduce_drops_stragglers(mesh8, rng):
    x = rng.standard_normal((8, 10)).astype(np.float32)
    alive = np.array([1, 1, 0, 1, 1, 1, 0, 1], dtype=bool)  # 2 stragglers

    def f(xl, al):
        out, count = topology.masked_all_reduce(xl[0], al[0], "data")
        return out[None], count.reshape(1)

    out, count = smap(f, mesh8, (P("data", None), P("data")),
                      (P("data", None), P("data")))(
        jnp.asarray(x), jnp.asarray(alive))
    want = x[alive].mean(axis=0)
    np.testing.assert_allclose(np.asarray(out)[0], want, rtol=1e-5, atol=1e-5)
    assert np.asarray(count)[0] == 6.0


def test_masked_all_reduce_all_dead_is_safe(mesh8):
    x = jnp.ones((8, 4))
    alive = jnp.zeros((8,), bool)

    def f(xl, al):
        out, count = topology.masked_all_reduce(xl[0], al[0], "data")
        return out[None], count.reshape(1)

    out, count = smap(f, mesh8, (P("data", None), P("data")),
                      (P("data", None), P("data")))(x, alive)
    assert np.all(np.isfinite(np.asarray(out)))  # no div-by-zero NaN


# ---------------------------------------------------------------------------
# engine facade (the MPI-transparency layer)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "acis", "acis_compressed",
                                     "acis_hierarchical"])
def test_engine_gradient_sync_backends_agree(mesh24, rng, backend):
    g = {"w": rng.standard_normal((8, 24)).astype(np.float32),
         "b": rng.standard_normal((8, 7)).astype(np.float32)}
    eng = make_engine(backend, inner_axis="data", outer_axis="pod")

    def f(wl, bl):
        grads = {"w": wl[0, 0], "b": bl[0, 0]}
        state = eng.init_state(grads)
        synced, _ = eng.gradient_sync(grads, state)
        return synced["w"][None, None], synced["b"][None, None]

    spec3 = P("pod", "data", None)
    w, b = smap(f, mesh24, (spec3, spec3), (spec3, spec3))(
        jnp.asarray(g["w"].reshape(2, 4, 24)),
        jnp.asarray(g["b"].reshape(2, 4, 7)))
    atol = 5e-2 if "compressed" in backend else 1e-4
    np.testing.assert_allclose(np.asarray(w)[0, 0], g["w"].mean(0), atol=atol)
    np.testing.assert_allclose(np.asarray(b)[0, 0], g["b"].mean(0), atol=atol)


def test_engine_rejects_unknown_backend():
    with pytest.raises(ValueError):
        make_engine("nccl")


# ---------------------------------------------------------------------------
# bounded compile cache + deprecation of the eager masked path
# ---------------------------------------------------------------------------

def test_compile_cache_is_bounded_lru():
    """A serving process streams an open-ended set of (shape, mesh) keys;
    the cache must evict least-recently-used past the knob and count the
    evictions so the leak stays observable."""
    from repro.obs import metrics as obs

    prev = topology.set_compile_cache_size(2)
    saved = dict(topology._COMPILE_CACHE)
    topology._COMPILE_CACHE.clear()
    try:
        with obs.recording() as rec:
            topology._cache_put(("k", 1), "a")
            topology._cache_put(("k", 2), "b")
            assert topology._cache_get(("k", 1)) == "a"    # 1 becomes MRU
            topology._cache_put(("k", 3), "c")             # evicts 2, not 1
            assert topology._cache_get(("k", 2)) is None
            assert topology._cache_get(("k", 1)) == "a"
            assert len(topology._COMPILE_CACHE) == 2
        assert rec.counter("topology.compile_cache_evicted") == 1

        with obs.recording() as rec:
            assert topology.set_compile_cache_size(1) == 2  # returns prev
        assert len(topology._COMPILE_CACHE) == 1            # shrink evicts
        assert rec.counter("topology.compile_cache_evicted") == 1
    finally:
        topology.set_compile_cache_size(prev)
        topology._COMPILE_CACHE.clear()
        topology._COMPILE_CACHE.update(saved)


def test_masked_all_reduce_is_deprecated(mesh8):
    """The eager helper survives as a wrapper, but points callers at the
    compiled first-class op."""
    x = jnp.ones((8, 4))
    alive = jnp.ones((8,), bool)

    def f(xl, al):
        out, count = topology.masked_all_reduce(xl[0], al[0], "data")
        return out[None], count.reshape(1)

    with pytest.warns(DeprecationWarning, match="masked_reduce"):
        smap(f, mesh8, (P("data", None), P("data")),
             (P("data", None), P("data")))(x, jnp.asarray(alive))
