"""Type 1/2 collectives, wire codecs, and backend parity (8 devices)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import collectives
from repro.core.types import ADD, MAX, ARGMAX_WITH_PAYLOAD, WELFORD
from repro.core.wire import BF16, FP8, IDENTITY, int8_codec, quantize_int8, \
    dequantize_int8

N = 8


def smap(fn, mesh, in_specs, out_specs):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


# ---------------------------------------------------------------------------
# backend parity: acis must equal xla on the Type 1 subset
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op,monoid", [("psum", ADD), ("pmax", MAX)])
def test_backend_parity_allreduce(mesh8, rng, op, monoid):
    x = rng.standard_normal((N, 17)).astype(np.float32)

    def acis(xl):
        return collectives.all_reduce(xl[0], "data", monoid,
                                      backend="acis")[None]

    def xla(xl):
        return collectives.all_reduce(xl[0], "data", monoid,
                                      backend="xla")[None]

    a = np.asarray(smap(acis, mesh8, P("data", None), P("data", None))(
        jnp.asarray(x)))
    b = np.asarray(smap(xla, mesh8, P("data", None), P("data", None))(
        jnp.asarray(x)))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_xla_backend_rejects_user_defined_ops(mesh8):
    """The Type 1 fixed-function limitation, reified as an error."""
    with pytest.raises(ValueError, match="Type 1 fixed-op limitation"):
        def f(xl):
            return collectives.all_reduce(xl, "data", WELFORD, backend="xla")
        jax.shard_map(f, mesh=mesh8, in_specs=P("data"), out_specs=P("data"),
                      check_vma=False)(jnp.ones((8, 3, 3)))


# ---------------------------------------------------------------------------
# Type 2: user-defined monoids over user-defined datatypes
# ---------------------------------------------------------------------------

def test_allreduce_argmax_with_payload(mesh8, rng):
    vals = rng.standard_normal((N, 12)).astype(np.float32)
    payload = rng.standard_normal((N, 12)).astype(np.float32)

    def f(v, p):
        out_v, out_p = collectives.all_reduce(
            (v[0], p[0]), "data", ARGMAX_WITH_PAYLOAD, backend="acis",
            latency_optimal=True)
        return out_v[None], out_p[None]

    ov, op_ = smap(f, mesh8, (P("data", None), P("data", None)),
                   (P("data", None), P("data", None)))(
        jnp.asarray(vals), jnp.asarray(payload))
    winner = vals.argmax(axis=0)
    np.testing.assert_allclose(np.asarray(ov)[0], vals.max(axis=0), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(op_)[0],
                               payload[winner, np.arange(12)], rtol=1e-6)


def test_allreduce_welford_variance(mesh8, rng):
    """Type 2 'matrix/stateful datatype': distributed mean/var in one pass."""
    data = rng.standard_normal((N, 64)).astype(np.float32)

    def f(xl):
        x = xl[0]
        n0 = jnp.full(x.shape, 1.0, jnp.float32)
        m0 = x
        s0 = jnp.zeros_like(x)
        n, m, s = collectives.all_reduce(
            (n0, m0, s0), "data", WELFORD, backend="acis",
            latency_optimal=True)
        return (m[None], (s / n)[None])

    m, var = smap(f, mesh8, P("data", None),
                  (P("data", None), P("data", None)))(jnp.asarray(data))
    np.testing.assert_allclose(np.asarray(m)[0], data.mean(axis=0),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(var)[0], data.var(axis=0),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# wire codecs (Type 0 / Type 2 wire dtypes)
# ---------------------------------------------------------------------------

def test_quantize_roundtrip(rng):
    x = rng.standard_normal(1000).astype(np.float32) * 3.0
    q, s, size = quantize_int8(jnp.asarray(x))
    y = np.asarray(dequantize_int8(q, s, size))
    assert y.shape == x.shape
    np.testing.assert_allclose(y, x, atol=3.5 * np.abs(x).max() / 127)


@pytest.mark.parametrize("codec", [BF16, FP8])
def test_cast_codec_allreduce(mesh8, rng, codec):
    x = (rng.standard_normal((N, 32)) * 0.1).astype(np.float32)

    def f(xl):
        return collectives.all_reduce(xl[0], "data", ADD, codec=codec)[None]

    out = np.asarray(smap(f, mesh8, P("data", None), P("data", None))(
        jnp.asarray(x)))
    want = x.sum(axis=0)
    tol = 0.05 if codec is BF16 else 0.4
    np.testing.assert_allclose(out[0], want, atol=tol)


def test_int8_codec_allreduce_encoded_domain(mesh8, rng):
    """Per-hop dequant-add-requant (the in-switch aggregation program)."""
    x = rng.standard_normal((N, 512)).astype(np.float32)

    def f(xl):
        return collectives.all_reduce(xl[0], "data", ADD,
                                      codec=int8_codec())[None]

    out = np.asarray(smap(f, mesh8, P("data", None), P("data", None))(
        jnp.asarray(x)))
    want = x.sum(axis=0)
    # lossy: blockwise int8 at every hop; error bounded by hop count * lsb
    scale = np.abs(x).max() / 127
    assert np.max(np.abs(out[0] - want)) < scale * N * 2.5
    # all ranks agree exactly (deterministic ring)
    for i in range(1, N):
        np.testing.assert_array_equal(out[i], out[0])


def test_wire_ratio_accounting():
    assert BF16.wire_ratio == 0.5
    assert FP8.wire_ratio == 0.25
    c = int8_codec(256)
    assert abs(c.wire_ratio - (1 + 4 / 256) / 4) < 1e-9


# ---------------------------------------------------------------------------
# prefix scan public API & alltoall backends
# ---------------------------------------------------------------------------

def test_prefix_scan_matches_numpy(mesh8, rng):
    x = rng.standard_normal((N, 7)).astype(np.float32)

    def f(xl):
        return collectives.prefix_scan(xl[0], "data", ADD)[None]

    out = np.asarray(smap(f, mesh8, P("data", None), P("data", None))(
        jnp.asarray(x)))
    np.testing.assert_allclose(out, np.cumsum(x, axis=0), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("backend", ["acis", "xla"])
def test_all_to_all_backends(mesh8, rng, backend):
    chunk = 2
    x = rng.standard_normal((N, N * chunk)).astype(np.float32)

    def f(xl):
        return collectives.all_to_all(xl[0], "data", backend=backend)[None]

    out = np.asarray(smap(f, mesh8, P("data", None), P("data", None))(
        jnp.asarray(x)))
    xs = x.reshape(N, N, chunk)
    want = np.swapaxes(xs, 0, 1).reshape(N, N * chunk)
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)
