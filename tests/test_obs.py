"""repro.obs tests: metrics registry, Perfetto timelines, drift watchdog.

The acceptance bar (ISSUE 8): a recorded gradient_sync on the
{pod: 2, data: 4} topology exports a Perfetto-loadable ``.trace.json``
whose wave structure matches the ExecutionPlan; the same exporter works
on a raw ``SwitchSim`` report; and the drift watchdog recommends a
re-fit on x2-perturbed link parameters while staying quiet on
self-replay.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as acis
from repro import obs, tune
from repro.core import make_engine
from repro.cgra.simulate import SwitchSim
from repro.obs import metrics as obs_metrics
from repro.obs.drift import DriftWatchdog
from repro.obs.report import RunReport
from repro.obs.spans import StageSpan

AV = jax.ShapeDtypeStruct
N = 8


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_recorder_basics():
    rec = obs.Recorder()
    rec.count("a")
    rec.count("a", 2)
    rec.gauge("g", 7.5)
    rec.observe("h", 1.0)
    rec.observe("h", 3.0)
    rec.event("e", detail="x")
    assert rec.counter("a") == 3
    assert rec.counter("missing") == 0
    snap = rec.snapshot()
    assert snap["gauges"]["g"] == 7.5
    assert snap["hists"]["h"]["n"] == 2
    assert snap["hists"]["h"]["mean"] == 2.0
    assert snap["hists"]["h"]["min"] == 1.0
    assert snap["hists"]["h"]["max"] == 3.0
    assert snap["events"] == [{"name": "e", "detail": "x"}]
    assert json.loads(json.dumps(snap)) == snap      # JSON-able
    assert "a = 3" in rec.summary()
    rec.clear()
    assert rec.counter("a") == 0 and not rec.events


def test_recording_context_installs_and_restores():
    assert obs.current() is obs.null_recorder
    with obs.recording() as rec:
        assert obs.current() is rec
        assert rec.enabled
        obs_metrics.RECORDER.count("x")
        assert rec.counter("x") == 1
    assert obs.current() is obs.null_recorder


def test_null_recorder_noops():
    assert not obs.null_recorder.enabled
    obs.null_recorder.count("x")
    obs.null_recorder.observe("x", 1.0)
    obs.null_recorder.gauge("x", 1.0)
    obs.null_recorder.event("x")
    assert obs.null_recorder.counter("x") == 0
    assert not obs.null_recorder.events


def test_event_cap_never_grows_unbounded():
    rec = obs.Recorder()
    for _ in range(obs_metrics.MAX_EVENTS + 5):
        rec.event("e")
    assert len(rec.events) == obs_metrics.MAX_EVENTS
    assert rec.dropped_events == 5
    assert rec.snapshot()["dropped_events"] == 5


# ---------------------------------------------------------------------------
# shared stage-record schema (satellite: executor / tune dedup)
# ---------------------------------------------------------------------------

def test_stage_trace_is_stage_span():
    assert tune.StageTrace is StageSpan


def test_executor_instrument_emits_shared_spans():
    eng = make_engine("acis")
    c = eng.compile(
        lambda a, b: acis.map(lambda x, y: x * y + 1.0, a, b, name="mul"),
        in_avals=(AV((256,), jnp.float32),) * 2)
    with obs.recording() as rec:
        out, tr = tune.record_instrumented(
            c, jnp.ones(256), jnp.full(256, 2.0))
    assert all(isinstance(s, StageSpan) for s in tr.stages)
    assert tr.stages[0].t_start == 0.0                 # normalized
    assert all(s.duration >= 0 for s in tr.stages)
    assert rec.counter("exec.instrumented_stages") == len(tr.stages)
    assert rec.hists["exec.stage_s"].n == len(tr.stages)
    np.testing.assert_allclose(np.asarray(out[0]), np.full(256, 3.0))


# ---------------------------------------------------------------------------
# Perfetto export: acceptance on the {pod:2, data:4} gradient sync
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def hier_run():
    """A real gradient_sync program on {pod: 2, data: 4}, recorded on the
    dataplane simulator."""
    sizes = {"data": 4, "pod": 2}
    eng = make_engine("acis_hierarchical", inner_axis="data",
                      outer_axis="pod")
    grads = {"b": AV((7,), jnp.float32), "w": AV((4, 33), jnp.float32)}
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    compiled = eng._sync_program(treedef, tuple(leaves), None,
                                 axis_sizes=sizes)
    sim = SwitchSim(eng.topology(axis_size=sizes))
    rng = np.random.default_rng(0)
    # simulator leading dims follow topology order: inner (data=4) first
    xs = [rng.standard_normal((4, 2) + av.shape).astype(np.float32)
          for av in leaves]
    _, trace, report = tune.record_sim(compiled, sim, *xs)
    return eng, compiled, trace, report


def _x_events(events):
    return [e for e in events if e["ph"] == "X" and e["name"] != "inject"]


def test_perfetto_schema_round_trip(hier_run, tmp_path):
    _, compiled, trace, _ = hier_run
    path = tmp_path / "sync.trace.json"
    obs.timeline.save(path, trace, compiled.plan)
    loaded = json.loads(path.read_text())

    events = loaded["traceEvents"]
    assert events, "empty trace"
    for e in events:
        assert e["ph"] in ("M", "X", "i")
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        if e["ph"] == "X":
            assert isinstance(e["ts"], float) and e["ts"] >= 0
            assert isinstance(e["dur"], float) and e["dur"] >= 0
        if e["ph"] == "i":
            assert e["s"] == "p"
    # metadata names the process and every lane
    meta = {e["name"] for e in events if e["ph"] == "M"}
    assert {"process_name", "thread_name"} <= meta


def test_perfetto_wave_structure_matches_plan(hier_run):
    _, compiled, trace, _ = hier_run
    plan = compiled.plan
    tr = obs.chrome_trace(trace, plan)
    xs = _x_events(tr["traceEvents"])
    assert len(xs) == len(compiled.stages)

    # every slice's wave matches the ExecutionPlan's wave assignment...
    wave_of = {i: w for w, grp in enumerate(plan.waves) for i in grp}
    for e in xs:
        assert e["args"]["wave"] == wave_of[e["args"]["stage"]]
    # ...and one instant per plan wave marks the boundary
    instants = [e for e in tr["traceEvents"] if e["ph"] == "i"]
    assert len(instants) == plan.n_waves
    # waves start in order
    starts = [e["ts"] for e in sorted(instants,
                                      key=lambda e: e["args"]["wave"])]
    assert starts == sorted(starts)
    # one lane per axis, wave lane reserved at tid 0
    assert all(e["tid"] >= 1 for e in xs)
    assert all(e["tid"] == 0 for e in instants)


def test_exporter_parity_sim_report_vs_executor_schema(mesh8, rng):
    """One exporter, two sources: the raw SwitchSim report and the
    shared-schema ProgramTrace built from it agree event for event on
    the cgra_nas_is workload."""
    eng = make_engine("acis")
    c = eng.compile(lambda h, k: (acis.reduce(h), acis.all_to_all(k)),
                    in_avals=(AV((16,), jnp.float32),
                              AV((64,), jnp.float32)),
                    axis_size=N)
    assert c.stage_kinds() == ["allreduce+alltoall"]
    h = rng.standard_normal((N, 16)).astype(np.float32)
    k = rng.standard_normal((N, 64)).astype(np.float32)
    sim = SwitchSim(eng.topology(axis_size=N))
    _, trace, report = tune.record_sim(c, sim, h, k)

    ev_sim = obs.chrome_trace(report, c.plan)["traceEvents"]
    ev_exe = obs.chrome_trace(trace, c.plan)["traceEvents"]
    key = lambda e: (e["name"], e["tid"], e["ts"], e["dur"],
                     e["args"]["stage"], e["args"]["wave"])
    xs_sim = sorted(map(key, _x_events(ev_sim)))
    xs_exe = sorted(map(key, _x_events(ev_exe)))
    assert xs_sim == xs_exe
    # both JSON-serializable (sim rows carry Placement objects)
    json.dumps(ev_sim), json.dumps(ev_exe)


def test_instrumented_timeline_uses_local_lane():
    eng = make_engine("acis")
    c = eng.compile(
        lambda a: acis.map(lambda x: x + 1.0, a, name="inc"),
        in_avals=(AV((64,), jnp.float32),))
    _, tr = tune.record_instrumented(c, jnp.zeros(64))
    out = obs.chrome_trace(tr, c.plan)
    xs = _x_events(out["traceEvents"])
    assert xs and all("@" not in e["name"] for e in xs)
    lanes = {e["args"]["name"] for e in out["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "(local)" in lanes


# ---------------------------------------------------------------------------
# drift watchdog
# ---------------------------------------------------------------------------

def _nas_is(eng):
    return eng.compile(lambda h, k: (acis.reduce(h), acis.all_to_all(k)),
                       in_avals=(AV((16,), jnp.float32),
                                 AV((64,), jnp.float32)),
                       axis_size=N)


def _nas_inputs(rng):
    return (rng.standard_normal((N, 16)).astype(np.float32),
            rng.standard_normal((N, 64)).astype(np.float32))


def test_drift_quiet_on_self_replay(rng):
    eng = make_engine("acis")
    c = _nas_is(eng)
    sim = SwitchSim(eng.topology(axis_size=N))
    _, trace, _ = tune.record_sim(c, sim, *_nas_inputs(rng))
    wd = DriftWatchdog()
    for _ in range(2):
        assert wd.observe(c.plan, c.topology, trace) > 0
    assert wd.alerts() == []
    with obs.recording() as rec:
        assert not wd.refit_recommended()
    assert rec.counter("drift.flagged") == 0


def test_drift_fires_on_mismodeled_stage(rng):
    """A deliberately mis-modeled stage — measured durations x3 — must be
    flagged with the pooled ratio near 3."""
    eng = make_engine("acis")
    c = _nas_is(eng)
    sim = SwitchSim(eng.topology(axis_size=N))
    _, trace, _ = tune.record_sim(c, sim, *_nas_inputs(rng))
    slow = dataclasses.replace(trace, stages=tuple(
        dataclasses.replace(s, t_end=s.t_start + 3.0 * s.duration)
        for s in trace.stages))
    wd = DriftWatchdog()
    with obs.recording() as rec:
        for _ in range(2):
            wd.observe(c.plan, c.topology, slow)
        assert wd.refit_recommended()
    alerts = wd.alerts()
    assert alerts and alerts[0].ratio == pytest.approx(3.0, rel=0.35)
    assert alerts[0].drift > wd.threshold
    assert rec.counter("drift.flagged") >= 1
    assert any(n == "drift.refit_recommended" for n, _ in rec.events)
    assert "DRIFT" in wd.report()


def test_drift_recommends_refit_on_perturbed_links(rng):
    """x2-perturbed simulator link parameters drift every collective key
    past threshold, and the recommended re-fit actually runs."""
    eng = make_engine("acis")
    c = _nas_is(eng)
    sim = SwitchSim(eng.topology(axis_size=N))
    net = sim.nets["data"]
    sim.nets["data"] = dataclasses.replace(
        net, bw=net.bw * 0.5, fpga_link=net.fpga_link * 2.0)
    wd = DriftWatchdog()
    for _ in range(2):
        _, trace, _ = tune.record_sim(c, sim, *_nas_inputs(rng))
        wd.observe(c.plan, c.topology, trace)
    assert wd.refit_recommended()
    fit = wd.refit()                    # closes the loop: tune.fit
    assert isinstance(fit, tune.NetFit)
    assert fit.n_stages >= 1


def test_drift_rejects_bad_threshold():
    with pytest.raises(ValueError):
        DriftWatchdog(threshold=1.0)


# ---------------------------------------------------------------------------
# explain() symmetry (satellite) + RunReport surfacing
# ---------------------------------------------------------------------------

def test_explain_without_recording_says_so(hier_run):
    _, compiled, _, _ = hier_run
    out = compiled.explain()
    assert "no recording attached" in out
    assert "meas_us" not in out.splitlines()[1]      # no phantom columns


def test_explain_accepts_run_report(hier_run):
    _, compiled, trace, _ = hier_run
    rep = RunReport(trace, compiled=compiled)
    from_report = compiled.explain(trace=rep)
    from_trace = compiled.explain(trace=trace)
    assert from_report == from_trace
    assert "mispredict ratio (meas/model)" in from_report
    assert "meas_us" in from_report


def test_run_report_text_json_save(hier_run, tmp_path):
    _, compiled, trace, _ = hier_run
    rec = obs.Recorder()
    rec.count("compile.programs")
    rep = RunReport.from_run(compiled, trace, recorder=rec)
    text = rep.text()
    assert "drift watchdog" in text and "counters:" in text
    payload = rep.to_json()
    assert payload["trace"]["stages"] == len(trace.stages)
    assert payload["program"]["waves"] == compiled.plan.n_waves
    assert "refit_recommended" in payload["drift"]
    assert payload["metrics"]["counters"]["compile.programs"] == 1
    json.dumps(payload)
    p = rep.save(tmp_path / "report.json")
    assert json.loads(open(p).read())["name"] == rep.name
    t = rep.save_trace(tmp_path / "run.trace.json")
    assert json.loads(open(t).read())["traceEvents"]


def test_obs_cli_report_and_trace(hier_run, tmp_path, capsys):
    from repro.obs.__main__ import main

    _, _, trace, _ = hier_run
    src = tmp_path / "run.jsonl"
    tune.save_jsonl(src, trace)

    out = tmp_path / "run.trace.json"
    assert main(["trace", str(src), "-o", str(out)]) == 0
    loaded = json.loads(out.read_text())
    assert len(_x_events(loaded["traceEvents"])) == len(trace.stages)

    assert main(["report", str(src), "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["trace"]["stages"] == len(trace.stages)

    assert main(["report", str(src)]) == 0
    assert "trace" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# counters threaded through compile / sim / serve
# ---------------------------------------------------------------------------

def test_compile_and_sim_counters(rng):
    eng = make_engine("acis")
    with obs.recording() as rec:
        c = _nas_is(eng)
        sim = SwitchSim(eng.topology(axis_size=N))
        sim.run(c, *_nas_inputs(rng))
    assert rec.counter("compile.programs") >= 1
    assert rec.counter("emit.kernel_stage") \
        + rec.counter("emit.reference_stage") >= 1
    assert rec.counter("sim.runs") == 1
    assert rec.counter("sim.stages") == len(c.stages)
    assert rec.hists["plan.wave_width"].n == c.plan.n_waves
    assert rec.counter("cgra.placed") + rec.counter("cgra.host_fallback") \
        == len(c.stages)


def test_sync_cache_counters():
    eng = make_engine("acis")
    grads = {"w": AV((32,), jnp.float32)}
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    with obs.recording() as rec:
        a = eng._sync_program(treedef, tuple(leaves), None,
                              axis_sizes={"data": N})
        b = eng._sync_program(treedef, tuple(leaves), None,
                              axis_sizes={"data": N})
    assert a is b
    assert rec.counter("compile.cache_miss") == 1
    assert rec.counter("compile.cache_hit") == 1


def test_serve_engine_counters():
    from repro import configs
    from repro.models import Model
    from repro.serve.engine import Request, ServeEngine

    cfg = configs.get_smoke("acis-100m")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rec = obs.Recorder()
    eng = ServeEngine(model, params, slots=2, max_seq=64, recorder=rec)
    eng.submit(Request(rid=0, prompt=np.arange(3, dtype=np.int32),
                       max_new_tokens=2))
    done = eng.run_to_completion()
    assert len(done) == 1
    assert rec.counter("serve.ticks") >= 1
    assert rec.counter("serve.admitted") == 1
    assert rec.counter("serve.retired") == 1
    assert rec.hists["serve.decode_s"].n >= 1
    assert rec.gauges["serve.active"] >= 0
