"""Launch-path coverage at test scale: cell building, probe composition,
roofline parsing, shape applicability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import cells, shapes
from repro.roofline import analysis


def test_shape_matrix_counts():
    all_cells = shapes.all_cells()
    assert len(all_cells) == 40                      # 10 archs × 4 shapes
    runnable = shapes.runnable_cells()
    assert len(runnable) == 32                       # 8 long_500k skips
    skipped = set(all_cells) - set(runnable)
    assert all(s == "long_500k" for _, s in skipped)
    ok, reason = shapes.applicable("nemotron-4-15b", "long_500k")
    assert not ok and "full-attention" in reason
    assert shapes.applicable("rwkv6-1.6b", "long_500k")[0]
    assert shapes.applicable("recurrentgemma-9b", "long_500k")[0]


def test_input_specs_every_cell():
    """input_specs builds ShapeDtypeStructs for all 40 nominal cells."""
    for arch, shape in shapes.all_cells():
        ins = cells.input_specs(arch, shape)
        for leaf in jax.tree.leaves(ins):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
        cell = shapes.SHAPES[shape]
        if cell.kind == "train":
            assert ins["tokens"].shape == (cell.global_batch,
                                           cell.seq_len + 1)
        elif cell.kind == "decode":
            assert ins["token"].shape == (cell.global_batch,)
            assert "cache" in ins


def test_probe_composition_exact():
    """The linear solver recovers a synthetic P(p,m) exactly."""
    O, E, Lmb, Lstep = 7.0, 3.0, 2.0, 5.0

    def P(p, m):
        return O + m * E + p * (m * Lmb + Lstep)

    costs = {(1, 1): {"x": P(1, 1)}, (2, 1): {"x": P(2, 1)},
             (1, 2): {"x": P(1, 2)}, (2, 2): {"x": P(2, 2)}}
    got = cells.compose_probe_costs(costs, n_periods=24, mb_cell=8,
                                    kind="train")
    assert abs(got["x"] - P(24, 8)) < 1e-9
    got2 = cells.compose_probe_costs(
        {(1, 1): {"x": O + Lstep}, (2, 1): {"x": O + 2 * Lstep}},
        n_periods=24, mb_cell=1, kind="prefill")
    assert abs(got2["x"] - (O + 24 * Lstep)) < 1e-9


def test_collective_bytes_parser():
    hlo = """
  %all-reduce.1 = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
  %ag = (bf16[4,256]{1,0}, bf16[4,256]{1,0}) all-gather-start(%a, %b)
  %agd = bf16[4,256]{1,0} all-gather-done(%ag)
  %p = f32[8]{0} collective-permute(%y), source_target_pairs={{0,1}}
  %ignore = f32[999]{0} add(%p, %p)
"""
    out = analysis.collective_bytes(hlo)
    assert out["bytes"]["all-reduce"] == 16 * 128 * 4
    assert out["bytes"]["all-gather"] == 2 * 4 * 256 * 2  # start only
    assert out["bytes"]["collective-permute"] == 32
    assert out["counts"]["all-reduce"] == 1


def test_build_and_compile_smallest_cell(devices):
    """End-to-end lower+compile of a real cell on the 8-device test mesh
    (2×4 'data'×'model') — the same machinery the 512-chip dry-run uses."""
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    # shrink the shape cell so the test compiles in seconds
    small = shapes.ShapeCell("train_4k", 128, 8, "train")
    built = cells._build_with_cell(
        "rwkv6-1.6b", "train_4k", small, mesh,
        {"n_layers": 2, "scan_layers": False, "analysis_unroll": True,
         "attn_chunk": 128, "wkv_chunk": 64}, 2)
    compiled = built.lowered.compile()
    cost = compiled.cost_analysis()
    assert float(cost.get("flops", 0)) > 0
    roof = analysis.analyze(built, compiled)
    assert roof.t_compute > 0 and roof.bottleneck in (
        "compute", "memory", "collective")


def test_roofline_terms_math():
    r = analysis.Roofline(arch="x", shape="train_4k", mesh="16dx16m",
                          chips=256, flops=197e12, hbm_bytes=819e9,
                          coll_bytes=50e9, coll_detail={},
                          model_flops=197e12 * 256)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert abs(r.t_collective - 1.0) < 1e-9
    assert abs(r.useful_flops_ratio - 1.0) < 1e-9
    assert abs(r.roofline_fraction - 1.0) < 1e-9


def test_pure_dp_parallelism_specs(mesh_dm):
    from repro.sharding import rules
    shapes_t = {"layers": {"pos0_self": {"attn": {
        "wq": jax.ShapeDtypeStruct((2, 64, 64), jnp.bfloat16)}}}}
    tp = jax.tree.leaves(rules.param_specs(shapes_t, mesh_dm))[0]
    dp = jax.tree.leaves(rules.param_specs(shapes_t, mesh_dm,
                                           "pure_dp"))[0]
    assert "model" in str(tp) and "model" not in str(dp)
    assert rules.dp_axes(mesh_dm, "pure_dp") == ("data", "model")
