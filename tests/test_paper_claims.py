"""Validation against the paper's own claims (EXPERIMENTS.md §Validation).

The paper's evaluation is itself an emulation (its §V.A methodology with
Table II parameters); we rebuilt that emulator and check our numbers land
on the published claims:

  * Fig. 5: fused Allgather_op_Allgather — avg 1.98× vs MPI4py
  * Fig. 4: GCN at 24 nodes — avg 3.4× vs SKX cluster
  * Fig. 3: ACiS ≥ MPI for every collective/size/node-count, growing with n
  * Fig. 6: IS & MG benefit most among NPB; miniFE above NPB average
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import pytest

from benchmarks import figures
from repro.core import netmodel as nm


def test_fig5_mean_speedup_matches_paper():
    got = figures.fig5_mean_speedup()
    assert abs(got - 1.98) / 1.98 < 0.10, got   # within 10% of 1.98x


def test_fig5_speedup_grows_with_message_size():
    """Paper: "especially for larger message sizes"."""
    small = nm.mpi4py_allgather_op_allgather(3, 1024) / \
        nm.acis_allgather_op_allgather(3, 1024)
    large = nm.mpi4py_allgather_op_allgather(3, 4 << 20) / \
        nm.acis_allgather_op_allgather(3, 4 << 20)
    assert large > small


def test_fig4_mean_speedup_matches_paper():
    got = figures.fig4_mean_speedup(24)
    assert abs(got - 3.4) / 3.4 < 0.25, got     # within 25% of 3.4x


def test_fig4_every_dataset_speeds_up():
    for _, _, derived in figures.fig4_gcn(24):
        assert float(derived.split("=")[1]) > 1.0


def test_fig3_acis_wins_everywhere_and_scales():
    for base, acis in [(nm.mpi_allreduce, nm.acis_allreduce),
                       (nm.mpi_allgather, nm.acis_allgather),
                       (nm.mpi_bcast, nm.acis_bcast),
                       (nm.mpi_gather, nm.acis_gather)]:
        for n in (32, 64, 128):
            for m in (64, 4096, 1 << 20, 4 << 20):
                assert base(n, m) / acis(n, m) > 1.0, (base.__name__, n, m)
        # advantage grows with node count where the network itself merges
        # or replicates (allreduce, bcast — the paper's headline point);
        # gather/allgather carry identical wire volume in both systems,
        # so their ratios saturate toward the bandwidth bound instead.
        if base in (nm.mpi_allreduce, nm.mpi_bcast):
            assert base(128, 4096) / acis(128, 4096) >= \
                base(32, 4096) / acis(32, 4096)


def test_fig6_is_and_mg_benefit_most():
    """Paper: "the performance benefits for MG and IS are higher than for
    the others" (among NPB)."""
    sp = {r[0].split("_")[1]: float(r[2].split("=")[1])
          for r in figures.fig6_npb(128)}
    assert sp["IS"] > sp["LU"] and sp["IS"] > sp["SP"]
    assert sp["MG"] > sp["LU"] and sp["MG"] > sp["SP"]
    assert all(v >= 1.0 for v in sp.values())


def test_fused_beats_unfused_in_emulator():
    """Type 4 fusion is never a loss in the model."""
    for m in (1024, 1 << 16, 1 << 22):
        assert nm.acis_fused_allreduce_alltoall(64, 4096, m) <= \
            nm.mpi_allreduce_then_alltoall(64, 4096, m)


def test_compression_payoff_model():
    """Type 2 wire compression halves the bandwidth term of the inter-pod
    stage — the emulator agrees with the analytic ratio."""
    m = 8 << 20
    t_f32 = nm.acis_allreduce(64, m)
    t_int8 = nm.acis_allreduce(64, m // 2)   # int16 partials = 0.5x wire
    assert t_int8 < t_f32
