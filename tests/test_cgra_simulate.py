"""Dataplane simulator: numerics match shard_map, latency is reported.

The acceptance bar: for all four acis backends the simulator's outputs
match the shard_map execution of the *same* CompiledProgram (allclose),
and the report puts simulated latency next to the netmodel prediction.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import core as acis
from repro.core import make_engine
from repro.core.wire import BF16
from repro.cgra.simulate import SimReport, SwitchSim

AV = jax.ShapeDtypeStruct
N = 8


def smap(fn, mesh, in_specs, out_specs):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


@pytest.fixture(scope="module")
def mesh22():
    return jax.make_mesh((2, 2), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _sim(eng, sizes):
    return SwitchSim(eng.topology(axis_size=sizes))


# ---------------------------------------------------------------------------
# acceptance: four backends, simulator vs shard_map on the same program
# ---------------------------------------------------------------------------

def _sync_program(eng, backend, n_total):
    """A gradient-sync-shaped program (EF target/mean/residual on the
    compressed backends) with an explicit divisor so it runs outside
    shard_map too."""
    compressed = "compressed" in backend

    def sync(g, r):
        t = acis.map(lambda g_, r_: g_ + r_, g, r, name="ef_target")
        if compressed:
            red, dlv = acis.ef_reduce(t, axis="auto")
            out = acis.map(lambda y: y / n_total, red, name="mean")
            res = acis.map(lambda t_, d: t_ - d, t, dlv,
                           name="ef_residual")
            return out, res
        red = acis.reduce(t, axis="auto")
        return acis.map(lambda y: y / n_total, red, name="mean"), t

    hier = "hierarchical" in backend
    sizes = {"data": 2, "pod": 2} if hier else {"data": N}
    return eng.compile(sync, in_avals=(AV((4, 33), jnp.float32),) * 2,
                       axis_size=sizes), sizes


@pytest.mark.parametrize("backend", ["acis", "acis_compressed",
                                     "acis_hierarchical",
                                     "acis_hierarchical_compressed"])
def test_simulator_matches_shard_map(backend, mesh8, mesh22, rng):
    hier = "hierarchical" in backend
    eng = make_engine(backend, inner_axis="data",
                      outer_axis="pod" if hier else None)
    n_total = 4 if hier else N
    compiled, sizes = _sync_program(eng, backend, n_total)

    g = rng.standard_normal((n_total, 4, 33)).astype(np.float32)
    r = 0.01 * rng.standard_normal((n_total, 4, 33)).astype(np.float32)

    if hier:
        mesh, spec = mesh22, P("pod", "data", None, None)
        gg = g.reshape(2, 2, 4, 33)
        rr = r.reshape(2, 2, 4, 33)
        lead = 2
    else:
        mesh, spec = mesh8, P("data", None, None)
        gg, rr, lead = g, r, 1

    def f(gl, rl):
        idx = (0,) * lead
        out, res = compiled(gl[idx], rl[idx])
        expand = out[(None,) * lead]
        return expand, res[(None,) * lead]

    want_out, want_res = smap(f, mesh, (spec, spec), (spec, spec))(
        jnp.asarray(gg), jnp.asarray(rr))

    sim = _sim(eng, sizes)
    # simulator leading dims follow the topology order (inner first)
    sg = np.moveaxis(gg, 0, 1) if hier else gg
    sr = np.moveaxis(rr, 0, 1) if hier else rr
    (got_out, got_res), report = sim.run(compiled, sg, sr)
    if hier:
        got_out = np.moveaxis(got_out, 1, 0)
        got_res = np.moveaxis(got_res, 1, 0)

    atol = 5e-2 if "compressed" in backend else 1e-4
    np.testing.assert_allclose(got_out, np.asarray(want_out), atol=atol)
    np.testing.assert_allclose(got_res, np.asarray(want_res), atol=atol)

    # every stage reported, with simulated + analytic latency and the
    # stage's placement (or explicit fallback) attached
    assert isinstance(report, SimReport)
    assert len(report.stages) == len(compiled.stages)
    assert report.t_sim > 0
    assert report.t_model > 0
    for srow, st in zip(report.stages, compiled.stages):
        assert srow.kind == st.kind
        assert srow.t_sim >= 0
        assert srow.placement is st.placement


# ---------------------------------------------------------------------------
# individual stage kinds
# ---------------------------------------------------------------------------

def test_fig5_scan_allgather(mesh8, rng):
    eng = make_engine("acis")
    c = eng.compile(
        lambda x: acis.all_gather(acis.scan(acis.all_gather(x))),
        in_avals=(AV((8,), jnp.float32),), axis_size=N)
    assert c.stage_kinds() == ["scan+allgather"]
    x = rng.standard_normal((64,)).astype(np.float32)
    want = np.asarray(smap(lambda v: c(v)[0], mesh8, P("data"), P(None))(
        jnp.asarray(x)))
    got, rep = _sim(eng, N).run(c, x.reshape(N, 8))
    np.testing.assert_allclose(got[0], want, atol=1e-4)
    for row in got:
        np.testing.assert_allclose(row, want, atol=1e-4)


def test_nas_is_pair(mesh8, rng):
    eng = make_engine("acis")
    c = eng.compile(lambda h, k: (acis.reduce(h), acis.all_to_all(k)),
                    in_avals=(AV((16,), jnp.float32),
                              AV((64,), jnp.float32)),
                    axis_size=N)
    assert c.stage_kinds() == ["allreduce+alltoall"]
    h = rng.standard_normal((N, 16)).astype(np.float32)
    k = rng.standard_normal((N, 64)).astype(np.float32)
    wh, wk = smap(lambda a, b: tuple(o[None] for o in c(a[0], b[0])),
                  mesh8, (P("data"), P("data")),
                  (P("data"), P("data")))(jnp.asarray(h), jnp.asarray(k))
    (gh, gk), _ = _sim(eng, N).run(c, h, k)
    np.testing.assert_allclose(gh, np.asarray(wh), atol=1e-4)
    np.testing.assert_allclose(gk, np.asarray(wk))


def test_bcast_allreduce_map_chain(mesh8, rng):
    eng = make_engine("acis")
    c = eng.compile(
        lambda x: acis.map(lambda v: v + 1, acis.all_gather(
            acis.reduce_scatter(acis.bcast(x, root=3)))),
        in_avals=(AV((16,), jnp.float32),), axis_size=N)
    x = rng.standard_normal((N, 16)).astype(np.float32)
    want = np.asarray(smap(lambda v: c(v[0])[0][None], mesh8, P("data"),
                           P("data"))(jnp.asarray(x)))
    got, _ = _sim(eng, N).run(c, x)
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_bf16_wire_codec_reduce(mesh8, rng):
    eng = make_engine("acis")
    c = eng.compile(lambda x: acis.reduce(acis.wire(BF16, x)),
                    in_avals=(AV((32,), jnp.float32),), axis_size=N)
    x = rng.standard_normal((N, 32)).astype(np.float32)
    want = np.asarray(smap(lambda v: c(v[0])[0][None], mesh8, P("data"),
                           P("data"))(jnp.asarray(x)))
    got, _ = _sim(eng, N).run(c, x)
    np.testing.assert_allclose(got, want, atol=5e-3)


def test_ef_topk_matches(mesh8, rng):
    eng = make_engine("acis_compressed", compressor="topk")
    c = eng.compile(
        lambda x: acis.ef_reduce(x, axis="data", compressor="topk",
                                 topk_ratio=0.1)[0],
        in_avals=(AV((4, 32), jnp.float32),), axis_size=N)
    x = rng.standard_normal((N, 4, 32)).astype(np.float32)
    want = np.asarray(smap(lambda v: c(v[0])[0][None], mesh8, P("data"),
                           P("data"))(jnp.asarray(x)))
    got, rep = _sim(eng, N).run(c, x)
    np.testing.assert_allclose(got, want, atol=1e-4)
    # top-k fell back to the host → the sim charged the detour, and the
    # analytic column agrees it is a fallback stage
    assert not c.stages[0].placement.fits
    assert rep.stages[0].t_sim > 0


# ---------------------------------------------------------------------------
# latency accounting
# ---------------------------------------------------------------------------

def test_simulated_time_tracks_analytic_model(rng):
    """Not a bit-match — the DES and the closed form make different
    pipelining assumptions — but same stage, same order of magnitude."""
    eng = make_engine("acis")
    c = eng.compile(lambda x: acis.reduce(x),
                    in_avals=(AV((1 << 14,), jnp.float32),), axis_size=N)
    x = rng.standard_normal((N, 1 << 14)).astype(np.float32)
    _, rep = _sim(eng, N).run(c, x)
    (row,) = rep.stages
    assert row.t_model is not None
    assert 0.2 < row.t_sim / row.t_model < 5.0


def test_outer_dci_stage_costs_more_than_inner(rng):
    """Same payload, same ring length: the thin inter-pod tier must be
    simulated slower than the intra-pod tier."""
    eng = make_engine("acis_hierarchical", inner_axis="data",
                      outer_axis="pod")
    c = eng.compile(
        lambda x: acis.all_gather(
            acis.reduce_scatter(x, axis="data"), axis="pod"),
        in_avals=(AV((8, 32), jnp.float32),),
        axis_size={"data": 2, "pod": 2})
    assert c.stage_axes() == ["data", "pod"]
    x = rng.standard_normal((2, 2, 8, 32)).astype(np.float32)
    _, rep = _sim(eng, {"data": 2, "pod": 2}).run(c, x)
    by_axis = {r.axis: r for r in rep.stages}
    # RS output is 1/2 the bytes the AG moves back, yet DCI still loses
    assert by_axis["pod"].t_sim > by_axis["data"].t_sim


def test_fallback_stage_slower_than_placed(rng):
    """The same program on a too-small device must simulate slower —
    the host detour is charged, not ignored."""
    from repro.core.compiler import (Emit, FuseHops, Legalize,
                                     LowerTopology, SelectSchedule,
                                     compile_rank_local)
    from repro.cgra.device import CGRADevice
    from repro.cgra.mapper import PlaceCGRA

    def prog(x):
        return acis.reduce(acis.map(lambda v: jnp.tanh(v) * 2, x,
                                    name="body"))

    def build(device):
        pipeline = (Legalize(), LowerTopology(), FuseHops(),
                    SelectSchedule(), PlaceCGRA(device=device), Emit())
        return compile_rank_local(prog, "data", axis_size=N,
                                  in_avals=(AV((1 << 12,), jnp.float32),),
                                  pipeline=pipeline)

    big = build(CGRADevice())                  # default grid: fits
    tiny = build(CGRADevice(rows=1, cols=1, ops_per_pe=1))
    assert big.stages[0].placement.fits
    assert not tiny.stages[0].placement.fits

    x = np.random.default_rng(0).standard_normal((N, 1 << 12)) \
        .astype(np.float32)
    sim = SwitchSim({"data": N})
    _, rep_big = sim.run(big, x)
    _, rep_tiny = sim.run(tiny, x)
    assert rep_tiny.t_sim > rep_big.t_sim
    # numerics identical either way — fallback changes cost, not results
    out_big, _ = sim.run(big, x)
    out_tiny, _ = sim.run(tiny, x)
    np.testing.assert_allclose(out_big, out_tiny)


def test_report_table_renders():
    eng = make_engine("acis")
    c = eng.compile(lambda x: acis.reduce(x),
                    in_avals=(AV((64,), jnp.float32),), axis_size=N)
    x = np.ones((N, 64), np.float32)
    _, rep = _sim(eng, N).run(c, x)
    txt = rep.table()
    assert "sim_us" in txt and "model_us" in txt and "TOTAL" in txt


def test_input_grid_validation():
    eng = make_engine("acis")
    c = eng.compile(lambda x: acis.reduce(x))
    sim = SwitchSim({"data": N})
    with pytest.raises(ValueError, match="rank grid"):
        sim.run(c, np.ones((3, 4), np.float32))
    with pytest.raises(TypeError, match="inputs"):
        sim.run(c)


def test_sim_requires_default_pipeline_stage_ir():
    import dataclasses as dc

    eng = make_engine("acis")
    c = eng.compile(lambda x: acis.reduce(x))
    stripped = dc.replace(c.stages[0], ir=None)
    c.stages = [stripped]
    with pytest.raises(ValueError, match="StageIR"):
        SwitchSim({"data": N}).run(c, np.ones((N, 4), np.float32))


def test_fused_exclusive_scan_matches_shard_map(mesh8, rng):
    """Regression: the fused scan+allgather interpreter must honor the
    scan's `exclusive` flag (rank 0 gets the monoid identity block)."""
    eng = make_engine("acis")
    c = eng.compile(
        lambda x: acis.all_gather(acis.scan(acis.all_gather(x),
                                            exclusive=True)),
        in_avals=(AV((4,), jnp.float32),), axis_size=N)
    assert c.stage_kinds() == ["scan+allgather"]
    x = rng.standard_normal((N, 4)).astype(np.float32)
    want = np.asarray(smap(lambda v: c(v[0])[0][None], mesh8, P("data"),
                           P("data"))(jnp.asarray(x)))
    got, _ = _sim(eng, N).run(c, x)
    np.testing.assert_allclose(got[0], want[0], atol=1e-4)
