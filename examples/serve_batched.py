"""Batched serving demo: continuous batching + the compiled data path.

    PYTHONPATH=src python examples/serve_batched.py

Part 1 submits a burst of requests with heterogeneous prompt/generation
lengths to a 4-slot engine over the ~100M model (reduced config for
speed) and verifies every completion against an independent greedy
decode.

Part 2 reruns the same burst with the decode collectives compiled
through ``engine.compile``: the model runs rank-local under ``shard_map``
over a 2-way tensor-parallel mesh and every per-layer all-reduce is a
switch program from the process-wide :data:`repro.serve.PROGRAM_CACHE`.
A second engine replica then shows the point of the shared cache — zero
new compiles, all hits — and the decode program's ``explain()`` prints
the schedule the switch compiler picked.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, obs
from repro.models import Model
from repro.serve import PROGRAM_CACHE, Request, ServeCollectives, ServeEngine


def make_requests(cfg, rng, n=10):
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 3 + (i * 3) % 9)
                    .astype(np.int32),
                    max_new_tokens=4 + (i * 5) % 12)
            for i in range(n)]


def run_burst(eng, reqs):
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    done = eng.run_to_completion()
    dt = time.time() - t0
    gen = sum(len(c.tokens) for c in done)
    print(f"  {len(done)} completions, {gen} tokens, {eng.ticks} ticks "
          f"in {dt:.1f}s ({gen / dt:.1f} tok/s, "
          f"{gen / max(eng.ticks, 1):.2f} tok/tick)")
    return done


def main():
    cfg = configs.get_smoke("acis-100m")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(42)
    reqs = make_requests(cfg, rng)

    print("plain transport (single jit, network free):")
    eng = ServeEngine(model, params, slots=4, max_seq=96)
    done = run_burst(eng, reqs)

    # verify one completion against an oracle greedy decode
    req = reqs[3]
    toks = list(req.prompt)
    for _ in range(req.max_new_tokens):
        h, _ = model.forward(params, jnp.asarray([toks], jnp.int32))
        toks.append(int(np.asarray(model.logits(params, h))[0, -1].argmax()))
    want = toks[len(req.prompt):]
    got = next(c for c in done if c.rid == 3).tokens
    assert got == want, (got, want)
    print("  oracle check ✓")

    print("\ncompiled transport (tp=2, switch programs from the shared "
          "cache):")
    with obs.recording() as rec:
        sc = ServeCollectives(cfg, tp=2)
        eng = ServeEngine(model, params, slots=4, max_seq=96,
                          collectives=sc)
        run_burst(eng, make_requests(cfg, rng))
        print(f"  program cache: {PROGRAM_CACHE.stats()}")
        print(f"  decode p50 {rec.gauges['serve.decode_p50_s']*1e3:.1f}ms "
              f"p99 {rec.gauges['serve.decode_p99_s']*1e3:.1f}ms")

        # a second replica reuses every program — no recompiles
        miss0 = PROGRAM_CACHE.stats()["misses"]
        eng2 = ServeEngine(model, params, slots=4, max_seq=96,
                           collectives=ServeCollectives(cfg, tp=2))
        run_burst(eng2, make_requests(cfg, rng))
        stats = PROGRAM_CACHE.stats()
        print(f"  replica 2: {stats['misses'] - miss0} new compiles, "
              f"{stats['hits']} total hits")

    name, prog, count = sc.decode_programs(4)[0]
    print(f"\ndecode tick runs {count}× {name}:")
    print(prog.explain())


if __name__ == "__main__":
    main()
