"""Batched serving demo: continuous batching with per-slot positions.

    PYTHONPATH=src python examples/serve_batched.py

Submits a burst of requests with heterogeneous prompt/generation lengths
to a 4-slot engine over the ~100M model (reduced config for speed) and
verifies every completion against an independent greedy decode.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import Model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = configs.get_smoke("acis-100m")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(42)

    eng = ServeEngine(model, params, slots=4, max_seq=96)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 3 + (i * 3) % 9)
                    .astype(np.int32),
                    max_new_tokens=4 + (i * 5) % 12)
            for i in range(10)]
    for r in reqs:
        eng.submit(r)

    t0 = time.time()
    done = eng.run_to_completion()
    dt = time.time() - t0
    gen_tokens = sum(len(c.tokens) for c in done)
    print(f"{len(done)} completions, {gen_tokens} tokens, "
          f"{eng.ticks} engine ticks in {dt:.1f}s "
          f"({gen_tokens / dt:.1f} tok/s, "
          f"{gen_tokens / max(eng.ticks, 1):.2f} tok/tick — continuous "
          f"batching keeps slots busy)")

    # verify one completion against an oracle greedy decode
    req = reqs[3]
    toks = list(req.prompt)
    for _ in range(req.max_new_tokens):
        h, _ = model.forward(params, jnp.asarray([toks], jnp.int32))
        toks.append(int(np.asarray(model.logits(params, h))[0, -1].argmax()))
    want = toks[len(req.prompt):]
    got = next(c for c in done if c.rid == 3).tokens
    assert got == want, (got, want)
    print("oracle check ✓")


if __name__ == "__main__":
    main()
