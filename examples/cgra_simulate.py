"""Map a switch program onto the CGRA and simulate its dataplane.

Run with:

    PYTHONPATH=src python examples/cgra_simulate.py

No mesh and no shard_map needed: the compiler's PlaceCGRA pass maps
every stage's compute body onto the paper's §IV switch grid (or falls
back to the host with an explicit reason), and the discrete-event
simulator executes the compiled program across 8 simulated ranks in this
one process — checking the numerics against plain numpy and printing the
simulated latency next to the analytic netmodel prediction.
"""

import numpy as np

import jax.numpy as jnp
import jax

from repro import core as acis
from repro.cgra.simulate import SwitchSim

AV = jax.ShapeDtypeStruct


def main():
    rng = np.random.default_rng(0)
    n = 8

    # -- Fig. 5: AG ∘ prefix-scan ∘ AG, fused to one in-network stage ----
    eng = acis.make_engine("acis")
    fig5 = eng.compile(
        lambda x: acis.all_gather(acis.scan(acis.all_gather(x))),
        in_avals=(AV((2048,), jnp.float32),), axis_size=n)
    print(fig5.explain(), "\n")

    x = rng.standard_normal((n, 2048)).astype(np.float32)
    sim = SwitchSim(eng.topology(axis_size=n))
    out, report = sim.run(fig5, x)
    err = np.abs(out[0] - np.cumsum(x.reshape(-1))).max()
    print(report.table())
    print(f"numerics vs numpy cumsum: max err {err:.2e}\n")

    # -- compressed sync: the int8 compressor is *placed*, top-k is not --
    engc = acis.make_engine("acis_compressed")
    for compressor in ("int8", "topk"):
        prog = engc.compile(
            lambda v: acis.ef_reduce(v, axis="data",
                                     compressor=compressor)[0],
            in_avals=(AV((16384,), jnp.float32),), axis_size=n)
        (st,) = prog.stages
        print(f"ef_reduce[{compressor}]: {st.placement.describe()}")
        g = rng.standard_normal((n, 16384)).astype(np.float32)
        _, rep = sim.run(prog, g)
        print(f"  simulated {rep.t_sim * 1e6:8.2f} us   "
              f"analytic {rep.t_model * 1e6:8.2f} us")
    print()

    # -- hierarchical pod mesh: per-tier links, codec on the thin hop ----
    engh = acis.make_engine("acis_hierarchical_compressed",
                            inner_axis="data", outer_axis="pod")
    sizes = {"data": 4, "pod": 2}
    sync = engh.compile(lambda g: acis.reduce(g, axis="auto"),
                        in_avals=(AV((16384,), jnp.float32),),
                        axis_size=sizes)
    print(sync.explain(), "\n")
    g = rng.standard_normal((4, 2, 16384)).astype(np.float32)
    simh = SwitchSim(engh.topology(axis_size=sizes))
    out, rep = simh.run(sync, g)
    err = np.abs(out - g.reshape(8, 16384).sum(0)).max() \
        / np.abs(g).sum(0).max()
    print(rep.table())
    print(f"hierarchical sum vs numpy (int8-lossy, relative): {err:.2e}")


if __name__ == "__main__":
    main()
