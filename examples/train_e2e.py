"""End-to-end driver: train the ~100M-param model with ACiS gradient sync.

    PYTHONPATH=src python examples/train_e2e.py \
        --backend acis_compressed --steps 300

Demonstrates the whole stack at laptop scale: synthetic bigram data →
composable model → explicit in-network gradient sync (shared-scale int8
with error feedback — Types 2+3) → AdamW → checkpoints → resume.  Loss
must descend toward the bigram entropy floor; the final report prints the
wire-bytes saving of the compressed transport vs f32.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core import make_engine
from repro.data.pipeline import BigramStream, DataConfig
from repro.models import Model
from repro.train import optimizer as opt_lib
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.step import (build_train_step_acis,
                              build_train_step_gspmd, init_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="acis_compressed",
                    choices=["xla", "acis", "acis_compressed",
                             "acis_hierarchical"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--arch", default="acis-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CI-sized)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else \
        configs.get(args.arch)
    model = Model(cfg)
    print(f"model {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"backend={args.backend}")

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    optimizer = opt_lib.adamw(opt_lib.warmup_cosine(3e-4, 20, args.steps))

    if args.backend == "xla":
        step = build_train_step_gspmd(model, optimizer, mesh, donate=False)
        engine = None
        state = init_state(model, optimizer, jax.random.key(0), engine)
    else:
        engine = make_engine(args.backend, inner_axis="data")
        # donate the state so the persistent gradient-sync bucket arenas
        # (init_state arenas=True) are written in place every step — the
        # pack transient is ~1x bucket size instead of 2x
        step = build_train_step_acis(model, optimizer, mesh, engine,
                                     donate=True)
        state = init_state(model, optimizer, jax.random.key(0), engine,
                           mesh=mesh, arenas=True)
        if state.sync_arenas is not None:
            sizes = [int(np.prod(a.shape)) * a.dtype.itemsize
                     for a in state.sync_arenas]
            print(f"sync arenas: {len(sizes)} buckets, "
                  f"{sum(sizes) / 1e6:.1f} MB (donated in place)")
    stream = BigramStream(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=7))
    print(f"data: bigram entropy floor = {stream.entropy():.3f} nats")

    loop = TrainLoop(step, stream, LoopConfig(
        total_steps=args.steps, log_every=max(args.steps // 20, 1),
        ckpt_every=max(args.steps // 4, 1), ckpt_dir=args.ckpt_dir))

    with jax.set_mesh(mesh):
        state = loop.maybe_restore(state)
        t0 = time.time()
        state = loop.run(state)
        dt = time.time() - t0

    if engine is not None and engine.last_sync_program() is not None:
        # the compiled switch program gradient_sync actually ran: the
        # Coalesce buckets and the ExecutionPlan wave structure per stage
        compiled_sync = engine.last_sync_program()
        print("\ngradient-sync switch program "
              f"(analytic {compiled_sync.program_time() * 1e6:.1f}us/sync):")
        print(compiled_sync.explain())

    first = loop.metrics_log[0]["nll"]
    last = loop.metrics_log[-1]["nll"]
    print("\nstep,nll,accuracy")
    for m in loop.metrics_log:
        print(f"{m['step']},{m['nll']:.4f},{m['accuracy']:.4f}")
    toks = args.steps * args.batch * args.seq
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({toks / dt:.0f} tok/s); nll {first:.3f} → {last:.3f} "
          f"(floor {stream.entropy():.3f})")
    if engine is not None and engine.compressed:
        params = sum(int(np.prod(p.shape))
                     for p in jax.tree.leaves(state.params))
        print(f"wire per sync: f32 ring {2 * 4 * params / 1e6:.1f} MB-eq "
              f"→ int16-partials {2 * 2 * params / 1e6:.1f} MB-eq "
              f"(+1/256 scales) — 2.0x reduction, EF-exact")
    bar = 0.5 if args.steps >= 200 else 0.1
    assert last < first - bar, \
        f"training failed to descend ({first:.3f} -> {last:.3f})"
    print("OK")


if __name__ == "__main__":
    main()
