"""Tour of the ACiS taxonomy on a live mesh (Types 0-4).

    PYTHONPATH=src python examples/fused_collectives.py

Runs every taxonomy level through the engine on 8 host devices and prints
the wire-bytes accounting next to each (what a switch/link would carry).
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import core as acis
from repro.core import collectives, fused
from repro.core.lookaside import (distributed_prefix_sum,
                                  error_feedback_all_reduce,
                                  powersgd_all_reduce)
from repro.core.types import ADD, MAX
from repro.core.wire import BF16


def smap(fn, mesh, in_specs, out_specs):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


def main():
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(0)
    n, dim = 8, 1 << 16
    x = jnp.asarray(rng.standard_normal((n, dim)).astype(np.float32))
    f32_wire = 2 * (n - 1) / n * dim * 4

    # Type 0/1: ring allreduce with a bf16 wire codec
    f = smap(lambda v: collectives.all_reduce(v[0], "data", ADD,
                                              codec=BF16)[None],
             mesh, P("data", None), P("data", None))
    out = f(x)
    print(f"Type 0+1  bf16-wire ring allreduce      "
          f"wire/elt {f32_wire * 0.5 / dim:.2f}B (f32: {f32_wire / dim:.2f}B)"
          f"  err={float(jnp.max(jnp.abs(out[0] - x.sum(0)))):.3f}")

    # Type 2: max-reduce (works on acis; xla psum can't take custom monoids)
    f = smap(lambda v: collectives.all_reduce(v[0], "data", MAX)[None],
             mesh, P("data", None), P("data", None))
    print(f"Type 2    user monoid (max) allreduce    ✓ "
          f"match={bool(jnp.allclose(f(x)[0], x.max(0)))}")

    # Type 3: stateful compressed sync with error feedback
    def ef(v):
        red, res = error_feedback_all_reduce(
            v[0], jnp.zeros((dim,), jnp.float32), "data")
        return red[None], res[None]
    f = smap(ef, mesh, P("data", None), (P("data", None), P("data", None)))
    red, res = f(x)
    print(f"Type 3    int8+EF allreduce              wire/elt ~2.0B  "
          f"residual|max|={float(jnp.max(jnp.abs(res))):.4f} "
          f"(look-aside memory)")

    # Type 3: the loop-inside-collective (PowerSGD rank-4)
    m = jnp.asarray(rng.standard_normal((n, 128, 64)).astype(np.float32))
    q0 = jnp.asarray(rng.standard_normal((64, 4)).astype(np.float32))
    def psgd(v, q):
        red, q2, res = powersgd_all_reduce(
            v[0], q, jnp.zeros((128, 64), jnp.float32), "data")
        return red[None]
    f = smap(psgd, mesh, (P("data", None, None), P(None, None)),
             P("data", None, None))
    _ = f(m, q0)
    print(f"Type 3    PowerSGD rank-4 allreduce      wire "
          f"{4 * 4 * (128 + 64)}B vs dense {128 * 64 * 4}B "
          f"({128 * 64 * 4 / (4 * 4 * (128 + 64)):.1f}x less)")

    # Type 4: fused allgather_op_allgather vs two rounds
    f_fused = smap(lambda v: fused.allgather_op_allgather(v, "data"),
                   mesh, P("data"), P(None))
    flat = x.reshape(-1)[:n * 1024]
    got = f_fused(flat)
    print(f"Type 4    allgather_op_allgather fused   one gather round "
          f"(baseline: two)  match="
          f"{bool(jnp.allclose(got, jnp.cumsum(flat), atol=1e-2))}")

    # Type 4: traced multi-tensor program through the pass pipeline —
    # map∘reduce on one input rides next to an alltoall on the other,
    # with the schedule chosen from the payload bytes.
    eng = acis.make_engine("acis", latency_optimal_below=16384)

    def histshuf(hist, keys):
        return acis.reduce(acis.map(jnp.square, hist)), \
            acis.all_to_all(keys)

    fprog = eng.compile(
        histshuf, mesh, (P("data", None), P("data")),
        (P("data", None), P("data")),
        in_avals=(jnp.zeros((1, 128), jnp.float32),
                  jnp.zeros((1024,), jnp.float32)))
    h, k = fprog(jnp.ones((n, 128)), jnp.arange(float(n * 1024)))
    print(f"Type 4    traced DAG program            stages={fprog.stages} "
          f"schedules={[s or '-' for s in fprog.schedules]}")

    # Type 4: collective matmul (compute rides the ring)
    xm = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
    wm = jnp.asarray(rng.standard_normal((32, 64)).astype(np.float32))
    f = smap(lambda a, b: fused.allgather_matmul(a, b, "data"),
             mesh, (P("data", None), P(None, "data")), P(None, "data"))
    got = f(xm, wm)
    print(f"Type 4    collective matmul              per-hop MAC hides "
          f"rotation  match={bool(jnp.allclose(got, xm @ wm, atol=1e-3))}")


if __name__ == "__main__":
    main()
