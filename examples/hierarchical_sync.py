"""Topology-aware compilation on a pod mesh.

Run with:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/hierarchical_sync.py

One reduce over ``axis="auto"`` is all the program says; the compiler's
LowerTopology pass knows the mesh has a fast intra-pod axis ("data") and
a ~10x thinner inter-pod axis ("pod"), lowers the reduce to the
hierarchical RS(data) -> AR(pod) -> AG(data) schedule, and places the
engine's wire codec on the thin inter-pod hop only.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import core as acis  # noqa: E402


def main():
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    print(f"mesh: pod=2 x data=4 ({len(jax.devices())} host devices)\n")

    for backend in ("acis_hierarchical", "acis_hierarchical_compressed"):
        eng = acis.make_engine(backend, inner_axis="data", outer_axis="pod")
        compiled = eng.compile(
            lambda g: acis.reduce(g, axis="auto"),
            in_avals=(jax.ShapeDtypeStruct((1 << 16,), jnp.float32),),
            axis_size={"data": 4, "pod": 2})

        print(f"== {backend} ==")
        print("program: reduce(g, axis='auto')")
        # the compiled program explains itself: kind/axis/schedule/codec
        # and the CGRA placement (or host fallback) per stage
        print(compiled.explain())
        red = next(nd.op for nd in compiled.source.nodes
                   if nd.op.kind.value == "reduce")
        print(f"  -> wire codec on the inter-pod hop: {red.codec.name}\n")

    # and the whole gradient-sync path, end to end on the mesh
    eng = acis.make_engine("acis_hierarchical", inner_axis="data",
                           outer_axis="pod")
    rng = np.random.default_rng(0)
    g = rng.standard_normal((8, 1000)).astype(np.float32)

    def sync(gl):
        synced, _ = eng.gradient_sync({"g": gl[0, 0]}, None)
        return synced["g"][None, None]

    fn = jax.jit(jax.shard_map(sync, mesh=mesh,
                               in_specs=P("pod", "data", None),
                               out_specs=P("pod", "data", None),
                               check_vma=False))
    out = np.asarray(fn(jnp.asarray(g.reshape(2, 4, 1000))))
    err = np.abs(out[0, 0] - g.mean(0)).max()
    print(f"gradient_sync vs flat mean: max err {err:.2e}")

    prog = eng._sync_program(
        jax.tree_util.tree_structure({"g": 0}),
        (jax.ShapeDtypeStruct((1000,), jnp.float32),))
    print("compiled sync stages:",
          [f"{k}@{a}" if a else k
           for k, a in zip(prog.stage_kinds(), prog.stage_axes())])


if __name__ == "__main__":
    main()
