"""Quickstart: the ACiS engine in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1.  Trace a switch program from a plain Python function (the paper's
    dataflow-graph front-end), compile it through the pass pipeline, and
    run it on an 8-device mesh — the Fig. 5 fused Allgather_op_Allgather
    in three lines.
2.  Trace a *two-tensor* program (the NAS-IS histogram/keys pair) — one
    fused in-network program with two inputs and two outputs.
3.  Run a Type 2 user-defined collective (Welford mean/variance) that a
    fixed-function switch cannot express.
4.  Forward a small assigned-architecture model through one step.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import core as acis
from repro.core import collectives
from repro.core.types import WELFORD
from repro import configs
from repro.models import Model


def main():
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    engine = acis.make_engine("acis")

    # -- 1. Type 4 fused collective via trace + the pass pipeline ------------
    def fem(x):
        return acis.all_gather(acis.scan(acis.all_gather(x)))

    # in_avals are the rank-local shapes: they size the schedule choice
    # (latency vs bandwidth ring) and keep program_time fully priced
    fn = engine.compile(fem, mesh, P("data"), P(None),
                        in_avals=(jax.ShapeDtypeStruct((4,), jnp.float32),))
    x = jnp.arange(32.0)
    out = fn(x)
    print("fused stages:", fn.stages)
    np.testing.assert_allclose(np.asarray(out), np.cumsum(np.asarray(x)),
                               rtol=1e-5)
    print("fig5 fused allgather_op_allgather ✓  (prefix sum in-network)")

    # -- 2. multi-tensor program: AR + A2A share one ring traversal ----------
    def histogram_shuffle(hist, keys):
        return acis.reduce(hist), acis.all_to_all(keys)

    fn2 = engine.compile(histogram_shuffle, mesh,
                         (P("data", None), P("data")),
                         (P("data", None), P("data")),
                         in_avals=(jax.ShapeDtypeStruct((1, 16),
                                                        jnp.float32),
                                   jax.ShapeDtypeStruct((8,),
                                                        jnp.float32)))
    hist = jnp.ones((8, 16)); keys = jnp.arange(64.0)
    h, k = fn2(hist, keys)
    print(f"nas-is fused stages: {fn2.stages}  "
          f"hist sum={float(h[0, 0]):.0f} (expect 8)")

    # -- 3. Type 2 user-defined collective ----------------------------------
    def welford_stats(xl):
        n0 = jnp.ones_like(xl)
        n, m, s = collectives.all_reduce((n0, xl, jnp.zeros_like(xl)),
                                         "data", WELFORD,
                                         latency_optimal=True)
        return m, s / n

    f = jax.jit(jax.shard_map(welford_stats, mesh=mesh,
                              in_specs=P("data"),
                              out_specs=(P("data"), P("data")),
                              check_vma=False))
    data = jnp.asarray(np.random.default_rng(0).standard_normal(64),
                       jnp.float32)
    mean, var = f(data)
    # positionwise stats across the 8 ranks (each holds 8 of 64 elements)
    ref = np.asarray(data).reshape(8, 8)
    print(f"welford in-network: mean={float(mean[0]):+.4f} "
          f"var={float(var[0]):.4f} "
          f"(numpy: {ref.mean(0)[0]:+.4f} {ref.var(0)[0]:.4f})")

    # -- 4. one of the assigned architectures, reduced config ----------------
    cfg = configs.get_smoke("qwen3-8b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.ones((2, 16), jnp.int32)
    hidden, _ = jax.jit(lambda p, t: model.forward(p, t))(params, toks)
    print(f"qwen3-8b (smoke) forward: hidden {hidden.shape} ✓")


if __name__ == "__main__":
    main()
