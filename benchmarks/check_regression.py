"""Benchmark regression guard.

    python -m benchmarks.check_regression BENCH_netmodel.json \\
        benchmarks/baseline.json

Diffs a fresh ``BENCH_netmodel.json`` against the committed baseline and
fails (exit 1) on any deterministic metric regressing by more than
``TOLERANCE``.  Keys are classified by direction: ``*speedup`` /
``*time_vs_f32`` are higher-is-better ratios, everything else is
lower-is-better — a latency in µs, or a size for ``*_bytes`` keys (the
execution plan's peak pack-transient memory).  ``jax_*`` keys are
wall-clock measurements of real executions — too noisy for a CI gate —
and are skipped; the analytic/emulated figures, the execution-plan
program times and the transient-memory accounting are deterministic, so
a >25% move there is a real model or compiler change, not jitter.
"""

from __future__ import annotations

import json
import sys

TOLERANCE = 0.25
NOISY_PREFIXES = ("jax_",)
HIGHER_IS_BETTER_SUFFIXES = ("speedup", "mean_speedup", "time_vs_f32")


def classify(key: str) -> str:
    if key.endswith(HIGHER_IS_BETTER_SUFFIXES):
        return "higher"
    return "lower"


def unit(key: str) -> str:
    return "B" if key.endswith("_bytes") else "us"


def check(fresh: dict, baseline: dict,
          tolerance: float = TOLERANCE) -> list[str]:
    failures = []
    for key, old in sorted(baseline.items()):
        if key.startswith(NOISY_PREFIXES):
            continue
        if not isinstance(old, (int, float)) or old <= 0:
            continue
        new = fresh.get(key)
        if new is None:
            failures.append(f"{key}: present in baseline, missing from "
                            "fresh results")
            continue
        if classify(key) == "higher":
            if new < old * (1.0 - tolerance):
                failures.append(
                    f"{key}: {old:.3f} -> {new:.3f} "
                    f"({new / old - 1.0:+.1%}, higher is better)")
        elif new > old * (1.0 + tolerance):
            u = unit(key)
            failures.append(
                f"{key}: {old:.3f}{u} -> {new:.3f}{u} "
                f"({new / old - 1.0:+.1%}, lower is better)")
    return failures


def main(argv: list[str]) -> int:
    if len(argv) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        fresh = json.load(f)
    with open(argv[1]) as f:
        baseline = json.load(f)
    checked = sum(1 for k, v in baseline.items()
                  if not k.startswith(NOISY_PREFIXES)
                  and isinstance(v, (int, float)) and v > 0)
    failures = check(fresh, baseline)
    if failures:
        print(f"REGRESSION: {len(failures)} of {checked} guarded metrics "
              f"moved >{TOLERANCE:.0%} vs {argv[1]}:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"benchmark guard OK: {checked} metrics within "
          f"{TOLERANCE:.0%} of {argv[1]}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
