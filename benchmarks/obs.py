import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# match benchmarks.run — process-local, nothing shared with tests

"""Observability benchmark: instrumentation cost + watchdog precision.

Three gated bounds and one recorded trajectory:

  * ``obs_instrument_overhead_frac`` — eager wall-clock of an
    instrumented run with recording *enabled* (spans + metrics emitted
    per stage) vs the same instrumented run against the null recorder,
    folded at the 5% acceptance floor: a passing run records exactly
    0.05, so the ratio is deterministic and the CI gate (25% tolerance)
    fails only when real emission overhead creeps past ~6%.  The cost
    of per-stage timing itself (the hook's ``block_until_ready``
    forfeits eager pipelining — inherent to the measurement, workload-
    dependent) is recorded separately, ungated, as
    ``jax_obs_instrument_block_us``.
  * ``obs_disabled_overhead_frac`` — the null-recorder cost: per-stage
    emission calls against the disabled default recorder, expressed as a
    fraction of a measured stage time and folded at 0.02 ("no measurable
    cost with recording off").
  * ``obs_drift_watchdog`` — the watchdog's symmetric drift reading on a
    x2-link-perturbed simulator (deterministic sim-vs-model math), with
    ``speedup=`` carrying detection precision: 1.0 means the perturbed
    run was flagged AND the unperturbed self-replay stayed quiet.
  * ``jax_obs_timeline_export_sync64`` — Perfetto export wall-clock for
    the 64-leaf ragged sync trace (``jax_`` prefix: recorded, ungated).

``write_trace`` dumps that same 64-leaf sync timeline as
``BENCH_sync64.trace.json`` — the loadable artifact CI uploads next to
the ``BENCH_*.json`` trajectories.
"""

import dataclasses
import time
import timeit

import numpy as np

from benchmarks.execplan import _ragged_sizes, _sync_program

TRACE_PATH = "BENCH_sync64.trace.json"

# acceptance floors (ISSUE 8): measured fractions below the floor fold
# to it, so passing runs are deterministic and the 25%-tolerance gate
# trips only on real cost creep
INSTRUMENT_FLOOR = 0.05
DISABLED_FLOOR = 0.02


def _eager_workload():
    """An axis-less multi-stage map pipeline the executor can run eagerly
    (collectives need shard_map; instrumented mode is eager-only)."""
    import jax
    import jax.numpy as jnp

    from repro.core import make_engine, tracing

    n_leaves, n_elems = 8, 1 << 16

    def prog(*xs):
        outs = []
        for i, x in enumerate(xs):
            y = tracing.map(lambda v: v * 1.0001 + 1.0, x,
                            name=f"scale{i}")
            outs.append(tracing.map(jnp.tanh, y, name=f"act{i}"))
        return tuple(outs)

    eng = make_engine("acis")
    avals = (jax.ShapeDtypeStruct((n_elems,), jnp.float32),) * n_leaves
    dag = tracing.trace(prog, num_inputs=n_leaves, name="obs_eager")
    compiled = eng.compile(dag, in_avals=avals)
    rng = np.random.default_rng(0)
    xs = [jnp.asarray(rng.standard_normal(n_elems).astype(np.float32))
          for _ in range(n_leaves)]
    return compiled, xs


def _fold(frac: float, floor: float) -> float:
    return max(float(frac), floor)


def overhead_rows() -> list[tuple]:
    """Instrumented vs plain eager wall-clock, and the null-recorder
    emission cost — both folded at their acceptance floors."""
    import jax

    from repro import obs, tune
    from repro.obs import metrics as _metrics

    compiled, xs = _eager_workload()

    def plain():
        jax.block_until_ready(compiled(*xs))

    def instrumented():
        jax.block_until_ready(compiled(*xs, instrument=[]))

    def recorded():
        with obs.recording():
            jax.block_until_ready(compiled(*xs, instrument=[]))

    meds = tune.interleaved_medians(
        {"plain": plain, "instr": instrumented, "rec": recorded},
        iters=9, warmup=2)
    frac = _fold(meds["rec"] / meds["instr"] - 1.0, INSTRUMENT_FLOOR)

    # disabled-path cost: the per-stage emission calls against the null
    # recorder, relative to a measured stage time
    records: list = []
    compiled(*xs, instrument=records)
    stage_s = max(np.mean([s.duration for s in records]), 1e-9)
    n_calls = 10000
    per_call = timeit.timeit(
        lambda: _metrics.RECORDER.count("bench.disabled"), number=n_calls
    ) / n_calls
    assert not _metrics.RECORDER.enabled      # measuring the null path
    disabled = _fold(2.0 * per_call / stage_s, DISABLED_FLOOR)

    return [
        ("obs_instrument_overhead_frac", frac,
         f"instr_us={meds['instr'] * 1e6:.1f}"
         f",rec_us={meds['rec'] * 1e6:.1f}"
         f",stages={len(records)},floor={INSTRUMENT_FLOOR}"),
        ("obs_disabled_overhead_frac", disabled,
         f"percall_ns={per_call * 1e9:.1f}"
         f",stage_us={stage_s * 1e6:.1f},floor={DISABLED_FLOOR}"),
        ("jax_obs_instrument_block_us",
         max(meds["instr"] - meds["plain"], 0.0) * 1e6,
         f"plain_us={meds['plain'] * 1e6:.1f}"
         f",instr_us={meds['instr'] * 1e6:.1f}"),
    ]


def _sync64(axis: int = 4):
    from repro.core import make_engine

    sizes = _ragged_sizes()
    compiled = _sync_program(sizes, make_engine("acis"), {"data": axis})
    rng = np.random.default_rng(0)
    ins = [rng.standard_normal((axis, s)).astype(np.float32)
           for s in sizes]
    return compiled, ins


def _record_sync64(compiled, ins, *, perturb: bool = False):
    from repro import tune
    from repro.cgra.simulate import SwitchSim

    sim = SwitchSim(compiled.topology)
    if perturb:
        net = sim.nets["data"]
        sim.nets["data"] = dataclasses.replace(
            net, bw=net.bw * 0.5, fpga_link=net.fpga_link * 2.0)
    _, trace, report = tune.record_sim(compiled, sim, *ins)
    return trace, report


def timeline_rows() -> list[tuple]:
    """Perfetto export wall-clock on the 64-leaf ragged sync trace."""
    from repro import obs

    compiled, ins = _sync64()
    trace, _ = _record_sync64(compiled, ins)
    t0 = time.perf_counter()
    out = obs.chrome_trace(trace, compiled.plan)
    dt = time.perf_counter() - t0
    return [("jax_obs_timeline_export_sync64", dt * 1e6,
             f"events={len(out['traceEvents'])}"
             f",stages={len(trace.stages)}")]


def drift_rows() -> list[tuple]:
    """Watchdog precision on deterministic simulator runs: the perturbed
    sim must be flagged, the faithful self-replay must not."""
    from repro.obs.drift import DriftWatchdog

    compiled, ins = _sync64()

    quiet = DriftWatchdog()
    loud = DriftWatchdog()
    for _ in range(2):
        trace, _ = _record_sync64(compiled, ins)
        quiet.observe(compiled.plan, compiled.topology, trace)
        bad, _ = _record_sync64(compiled, ins, perturb=True)
        loud.observe(compiled.plan, compiled.topology, bad)

    false_alarms = len(quiet.alerts())
    hits = loud.alerts()
    precision = 1.0 if hits and not false_alarms else 0.0
    drift = hits[0].drift if hits else 1.0
    return [("obs_drift_watchdog", drift,
             f"speedup={precision:.4f}"
             f",flagged={len(hits)},false_alarms={false_alarms}"
             f",worst_ratio={hits[0].ratio:.3f}" if hits else
             f"speedup={precision:.4f},flagged=0"
             f",false_alarms={false_alarms}")]


def rows() -> list[tuple]:
    return overhead_rows() + timeline_rows() + drift_rows()


def record(computed_rows: list | None = None) -> dict:
    """BENCH_obs.json payload: every row's value, plus ``name.speedup``
    for rows carrying one (the drift-precision gate) — same shape
    ``check_regression.py`` consumes."""
    out: dict = {}
    for name, val, derived in (computed_rows if computed_rows is not None
                               else rows()):
        out[name] = round(float(val), 6)
        for part in str(derived).split(","):
            k, _, v = part.partition("=")
            if k == "speedup":
                try:
                    out[f"{name}.speedup"] = round(float(v), 4)
                except ValueError:
                    pass
    return out


def write_trace(path: str = TRACE_PATH) -> str:
    """The 64-leaf sync Perfetto timeline, written as the CI artifact."""
    from repro import obs

    compiled, ins = _sync64()
    trace, _ = _record_sync64(compiled, ins)
    return obs.timeline.save(path, trace, compiled.plan)


if __name__ == "__main__":
    print("name,value,derived")
    for name, val, derived in rows():
        print(f"{name},{val},{derived}")
    print(write_trace())
