"""Analytic network emulator — re-exported from :mod:`repro.core.netmodel`.

The emulator moved into the package proper so the compiler's
``SelectSchedule`` pass can consult it (latency-vs-bandwidth ring choice)
without depending on the benchmarks tree.  This shim keeps the historical
``from benchmarks import netmodel`` import path working for the benchmark
runner and the paper-claims tests.
"""

from repro.core.netmodel import (  # noqa: F401
    NetParams, PAPER, torus_hops, _acis_base,
    mpi_allgather, mpi_allreduce, mpi_bcast, mpi_gather, mpi_alltoall,
    acis_allgather, acis_allreduce, acis_bcast, acis_gather, acis_alltoall,
    mpi4py_allgather_op_allgather, acis_allgather_op_allgather,
    mpi_allreduce_then_alltoall, acis_fused_allreduce_alltoall,
    ring_allreduce_time, ring_crossover_bytes,
    ICI, DCI, TIERS, ring_reduce_scatter_time, ring_all_gather_time,
    hierarchical_allreduce_time,
)
