import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# match benchmarks.run — process-local, nothing shared with tests

"""Elasticity benchmark: masked-sync overhead, recompile reuse, and the
sync-time-vs-dead-fraction degradation curve.

Three gated bounds (ISSUE 10):

  * ``elastic_masked_overhead`` — analytic ``program_time`` of the
    compiled *masked* gradient sync over the unmasked one at zero
    faults.  The masked lowering rides the same flat bucket ring (the
    live-count is one extra lane in the pack), so the only cost is the
    count lane plus the renormalize epilogue; the benchmark hard-asserts
    the ratio stays ≤ ``MASKED_OVERHEAD_GATE`` (1.05x) before recording
    it for the 25%-tolerance CI guard.
  * ``elastic_recompile_reuse`` — fraction of programs + arenas reused
    across shape-preserving rank dropout (``engine.recompile`` on a
    :class:`~repro.elastic.TopologyDelta`).  Membership is a runtime
    program input, so dropout must hit the caches 100%: the row
    hard-asserts reuse == 1.0 and carries it as ``speedup=`` so the
    guard treats it higher-is-better.
  * ``elastic_sync_dead_{0,1_16,1_8,1_4}`` — simulated end-to-end time
    of the masked sync on 16 ranks with 0/1/2/4 endpoint-dead ranks
    (``FaultPlan``, detection timeout 0.25x the healthy run).  The
    curve is hard-asserted monotone with no >2x adjacent cliff —
    degradation is the linear detection charge plus the contracted
    ring, not a collapse.

``write_trace`` dumps the 4-dead-rank simulated run as
``BENCH_faults.trace.json`` — the Perfetto artifact CI uploads next to
the ``BENCH_*.json`` trajectories, showing the dead ranks' silent lanes
and the live ranks' delayed start.
"""

import numpy as np

TRACE_PATH = "BENCH_faults.trace.json"

MASKED_OVERHEAD_GATE = 1.05
N_RANKS = 16
# (n_dead, row tag) — dead fractions 0, 1/16, 1/8, 1/4 of 16 ranks
DEAD_STEPS = ((0, "0"), (1, "1_16"), (2, "1_8"), (4, "1_4"))

# transformer-ish gradient pytree: two big matmul leaves, a small tail
LEAF_SHAPES = {"wq": (1 << 18,), "ffn": (1 << 17,), "bias": (1 << 10,),
               "norm": (1 << 8,)}


def _grads():
    import jax.numpy as jnp

    return {k: jnp.zeros(s, jnp.float32) for k, s in LEAF_SHAPES.items()}


def _sync_pair(engine, axis_sizes):
    """(unmasked, masked) compiled sync programs for the same pytree."""
    import jax

    gl = _grads()
    treedef = jax.tree_util.tree_structure(gl)
    avals = tuple(jax.ShapeDtypeStruct(l.shape, l.dtype)
                  for l in jax.tree_util.tree_leaves(gl))
    plain = engine._sync_program(treedef, avals, None,
                                 axis_sizes=axis_sizes, masked=False)
    masked = engine._sync_program(treedef, avals, None,
                                  axis_sizes=axis_sizes, masked=True)
    return plain, masked


def overhead_rows() -> list[tuple]:
    """Masked vs unmasked analytic program_time at zero faults, on both
    the flat and hierarchical pipelines — hard-gated at 1.05x."""
    from repro.core import make_engine

    out = []
    for tag, backend, sizes in (
            ("flat", "acis", {"data": 8}),
            ("hier", "acis_hierarchical", {"data": 4, "pod": 2})):
        kw = {"inner_axis": "data"}
        if "pod" in sizes:
            kw["outer_axis"] = "pod"
        plain, masked = _sync_pair(make_engine(backend, **kw), sizes)
        t_plain, t_masked = plain.program_time(), masked.program_time()
        ratio = t_masked / t_plain
        assert ratio <= MASKED_OVERHEAD_GATE, (
            f"masked sync overhead {ratio:.4f}x exceeds the "
            f"{MASKED_OVERHEAD_GATE}x gate ({tag})")
        out.append((f"elastic_masked_overhead_{tag}", ratio,
                    f"plain_us={t_plain * 1e6:.2f}"
                    f",masked_us={t_masked * 1e6:.2f}"
                    f",gate={MASKED_OVERHEAD_GATE}"
                    f",stages={len(masked.stages)}"))
    return out


def recompile_rows() -> list[tuple]:
    """Shape-preserving dropout must reuse 100% of programs + arenas;
    a shape-moving delta must compile fresh."""
    from repro.core import make_engine
    from repro.elastic import Membership, TopologyDelta

    eng = make_engine("acis_hierarchical", inner_axis="data",
                      outer_axis="pod")
    sizes = {"data": 4, "pod": 2}
    gl = _grads()
    # warm the program + arena caches, then drop ranks one at a time
    eng.init_arenas(gl, axis_sizes=sizes, masked=True)
    mem = Membership.all_alive(8)
    reports = [eng.recompile(mem.delta(mem.drop(r)), gl, axis_sizes=sizes)
               for r in (1, 5, 7)]
    reuse = min(r.reuse_frac for r in reports)
    assert reuse == 1.0 and not any(r.full_recompile for r in reports), \
        f"shape-preserving dropout missed a cache: {reports}"
    moved = eng.recompile(TopologyDelta(axis_sizes=(("data", 8),)), gl,
                          axis_sizes=sizes)
    assert moved.full_recompile, "shape-moving delta reused stale program"
    return [("elastic_recompile_reuse", reuse,
             f"speedup={reuse:.4f},drops={len(reports)}"
             f",shape_moving_rebuilt={moved.programs_rebuilt}")]


def _masked_program(n_ranks: int = N_RANKS):
    import jax
    import jax.numpy as jnp

    from repro.core import make_engine, tracing

    eng = make_engine("acis", inner_axis="data")

    def prog(x, alive):
        return tracing.masked_reduce(x, alive, axis="auto")

    compiled = eng.compile(
        prog, axis_size=n_ranks,
        in_avals=(jax.ShapeDtypeStruct((1 << 14,), jnp.float32),
                  jax.ShapeDtypeStruct((), jnp.float32)))
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n_ranks, 1 << 14)).astype(np.float32)
    return compiled, x


def _faulted_run(compiled, x, n_dead: int, timeout: float):
    from repro.cgra.simulate import FaultPlan, SwitchSim
    from repro import tune

    n_ranks = x.shape[0]
    dead = frozenset(range(n_dead))
    alive = np.ones((n_ranks,), np.float32)
    alive[list(dead)] = 0.0
    faults = (FaultPlan(dead=dead, detect_timeout_s=timeout)
              if n_dead else None)
    sim = SwitchSim(compiled.topology, faults=faults)
    (val, cnt), trace, report = tune.record_sim(compiled, sim, x, alive)
    # live ranks must hold the masked mean over the survivors
    want = x[n_dead:].mean(0)
    np.testing.assert_allclose(np.asarray(val)[n_ranks - 1], want,
                               atol=1e-5)
    assert float(np.asarray(cnt)[n_ranks - 1]) == n_ranks - n_dead
    return trace, report


def degradation_rows() -> list[tuple]:
    """Simulated masked-sync t_end at 0/1/2/4 dead of 16 — monotone,
    no >2x adjacent cliff."""
    compiled, x = _masked_program()
    _, healthy = _faulted_run(compiled, x, 0, 0.0)
    timeout = 0.25 * healthy.t_end

    out, prev = [], None
    for n_dead, tag in DEAD_STEPS:
        _, report = _faulted_run(compiled, x, n_dead, timeout)
        t = report.t_end
        if prev is not None:
            assert t >= prev * 0.999, \
                f"degradation not monotone at {tag}: {prev} -> {t}"
            assert t <= 2.0 * prev, \
                f"degradation cliff at {tag}: {prev} -> {t}"
        prev = t
        out.append((f"elastic_sync_dead_{tag}", t * 1e6,
                    f"n_dead={n_dead},n_live={N_RANKS - n_dead}"
                    f",timeout_us={timeout * 1e6:.2f}"))
    return out


def rows() -> list[tuple]:
    return overhead_rows() + recompile_rows() + degradation_rows()


def record(computed_rows: list | None = None) -> dict:
    """BENCH_elastic.json payload: every row's value, plus
    ``name.speedup`` for rows carrying one (the recompile-reuse gate) —
    same shape ``check_regression.py`` consumes."""
    out: dict = {}
    for name, val, derived in (computed_rows if computed_rows is not None
                               else rows()):
        out[name] = round(float(val), 6)
        for part in str(derived).split(","):
            k, _, v = part.partition("=")
            if k == "speedup":
                try:
                    out[f"{name}.speedup"] = round(float(v), 4)
                except ValueError:
                    pass
    return out


def write_trace(path: str = TRACE_PATH) -> str:
    """The 4-dead-of-16 masked sync timeline, written as the Perfetto
    CI artifact."""
    from repro import obs

    compiled, x = _masked_program()
    _, healthy = _faulted_run(compiled, x, 0, 0.0)
    trace, _ = _faulted_run(compiled, x, 4, 0.25 * healthy.t_end)
    return obs.timeline.save(path, trace, compiled.plan)


if __name__ == "__main__":
    print("name,value,derived")
    for name, val, derived in rows():
        print(f"{name},{val},{derived}")
    print(write_trace())
