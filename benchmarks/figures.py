"""Paper figures 3-6 via the network emulator + real JAX measurements.

One function per figure; each returns rows of (name, value, derived) the
runner prints as CSV.  The emulated numbers reproduce the paper's claims
(validated with tolerance bands in tests/test_paper_claims.py); the JAX
measurements run the actual engine on 8 host devices to show the fusion /
in-network wins on real executions of the same schedules.
"""

from __future__ import annotations

import time
from typing import Callable

import numpy as np

from repro.core import netmodel as nm

SIZES_SMALL = [2 ** i for i in range(2, 13)]            # 4 B .. 4 KB
SIZES_LARGE = [2 ** i for i in range(12, 23)]           # 4 KB .. 4 MB
NODE_COUNTS = [32, 64, 128]


# ---------------------------------------------------------------------------
# Fig. 3 — OSU collectives, ACiS vs MPI/SKX
# ---------------------------------------------------------------------------

def fig3_osu() -> list[tuple]:
    rows = []
    pairs = {
        "allgather": (nm.mpi_allgather, nm.acis_allgather),
        "allreduce": (nm.mpi_allreduce, nm.acis_allreduce),
        "bcast": (nm.mpi_bcast, nm.acis_bcast),
        "gather": (nm.mpi_gather, nm.acis_gather),
    }
    for name, (base, acis) in pairs.items():
        for n in NODE_COUNTS:
            for m in SIZES_SMALL + SIZES_LARGE:
                tb, ta = base(n, m), acis(n, m)
                rows.append((f"fig3_osu_{name}_n{n}_m{m}",
                             ta * 1e6, f"speedup={tb / ta:.2f}"))
    return rows


def fig3_summary() -> dict:
    out = {}
    for name, (base, acis) in {
            "allgather": (nm.mpi_allgather, nm.acis_allgather),
            "allreduce": (nm.mpi_allreduce, nm.acis_allreduce),
            "bcast": (nm.mpi_bcast, nm.acis_bcast),
            "gather": (nm.mpi_gather, nm.acis_gather)}.items():
        sp = [base(n, m) / acis(n, m)
              for n in NODE_COUNTS for m in SIZES_SMALL + SIZES_LARGE]
        out[name] = float(np.mean(sp))
    return out


# ---------------------------------------------------------------------------
# Fig. 5 — Allgather_op_Allgather (op = prefix sum)
# ---------------------------------------------------------------------------

FIG5_SIZES = [2 ** i for i in range(10, 23)]  # 1 KB .. 4 MB per rank


def fig5_emulated(n: int = 3) -> list[tuple]:
    rows = []
    for m in FIG5_SIZES:
        tb = nm.mpi4py_allgather_op_allgather(n, m)
        ta = nm.acis_allgather_op_allgather(n, m)
        rows.append((f"fig5_fusedAG_m{m}", ta * 1e6,
                     f"speedup={tb / ta:.2f}"))
    return rows


def fig5_mean_speedup(n: int = 3) -> float:
    sp = [nm.mpi4py_allgather_op_allgather(n, m)
          / nm.acis_allgather_op_allgather(n, m) for m in FIG5_SIZES]
    return float(np.mean(sp))


# ---------------------------------------------------------------------------
# Fig. 4 — GCN application scalability
# ---------------------------------------------------------------------------

GCN_DATASETS = {
    # name: (n_vertices, avg_degree, feature_dim)  [public dataset stats]
    "PPI": (56944, 28, 50),
    "Citeseer": (3327, 2.7, 3703),
    "Pubmed": (19717, 4.5, 500),
    "ogbn-mag": (1939743, 11, 128),
    "ogbn-products": (2449029, 50, 100),
}


GCN_HIDDEN = 128
HOST_SPMM_BW = 20e9          # sparse aggregation is memory-bound on SKX
HOST_GEMM_RATE = 300e9       # dense transform (multi-core SKX)


def _gcn_times(n_nodes: int, verts: int, deg: float, feat: int,
               p: nm.NetParams = nm.PAPER) -> tuple[float, float]:
    """One GCN training iteration (aggregate + transform), row-partitioned.

    Baseline: allgather the full feature matrix ((n-1)·m on the wire per
    rank), then aggregate at the endpoint (memory-bound SpMM) and apply
    the dense transform.
    ACiS: feature blocks are MAC-merged *in the fabric* (Type 3 look-aside
    against the switch HBM), so a rank sends its block once and receives
    only its own aggregated rows — the output volume is 1/n of the
    baseline gather, and aggregation rides the stream at line rate.  The
    dense transform stays at the endpoint in both systems.
    """
    m = verts * feat * 4 // n_nodes                 # per-rank feature bytes
    spmm_bytes = verts * deg * feat * 8 / n_nodes   # edge-gather traffic
    gemm = 2.0 * verts * feat * GCN_HIDDEN / n_nodes
    t_transform = gemm / HOST_GEMM_RATE
    t_base = nm.mpi_allgather(n_nodes, m, p) \
        + spmm_bytes / HOST_SPMM_BW + t_transform
    # the endpoint still folds received aggregates into its local state
    # and prepares the next layer (~half the SpMM traffic stays on-host)
    t_acis = nm._acis_base(n_nodes, p) \
        + 2 * m / p.bw + 0.5 * spmm_bytes / HOST_SPMM_BW \
        + (n_nodes - 1) * (p.fpga_link + p.port) + t_transform
    return t_base, t_acis


def fig4_gcn(n_nodes: int = 24) -> list[tuple]:
    rows = []
    for name, (v, d, f) in GCN_DATASETS.items():
        tb, ta = _gcn_times(n_nodes, v, d, f)
        rows.append((f"fig4_gcn_{name}_n{n_nodes}", ta * 1e6,
                     f"speedup={tb / ta:.2f}"))
    return rows


def fig4_mean_speedup(n_nodes: int = 24) -> float:
    sp = [(lambda t: t[0] / t[1])(_gcn_times(n_nodes, v, d, f))
          for v, d, f in GCN_DATASETS.values()]
    return float(np.mean(sp))


# ---------------------------------------------------------------------------
# Fig. 6 — NPB + miniFE proxies
# ---------------------------------------------------------------------------

# modeled per-iteration endpoint compute budgets (seconds) — the part of
# each proxy app the network cannot touch; sets the comm:compute ratio so
# whole-app speedups land in the regime of paper Fig. 6
APP_COMPUTE = {"IS": 3.0e-3, "MG": 2.0e-3, "LU": 30e-3, "SP": 25e-3,
               "miniFE": 0.7e-3}


def fig6_npb(n: int = 128) -> list[tuple]:
    """Whole-app per-iteration time, base vs ACiS.

    IS:     bucket-histogram allreduce + key alltoall  (fusable: Type 4)
    MG:     residual allreduces (tiny, latency-bound) + halos (unchanged)
    LU/SP:  pipelined sweeps — p2p dominated, small collective share
    miniFE: CG — two dot allreduces (latency-bound) + matvec halo
    """
    rows = []
    # IS: 2^23 keys/rank, 1024 buckets
    m_keys = (2 ** 23) * 4
    m_hist = 1024 * 4
    tb = nm.mpi_allreduce_then_alltoall(n, m_hist, m_keys) + APP_COMPUTE["IS"]
    ta = nm.acis_fused_allreduce_alltoall(n, m_hist, m_keys) \
        + APP_COMPUTE["IS"]
    rows.append((f"fig6_IS_n{n}", ta * 1e6, f"speedup={tb / ta:.2f}"))

    # MG: per V-cycle ~ 8 tiny allreduces + halos (halo unchanged)
    t_halo = 6 * (nm.PAPER.mpi_overhead + 32768 / nm.PAPER.bw)
    tb = 8 * nm.mpi_allreduce(n, 8) + t_halo + APP_COMPUTE["MG"]
    ta = 8 * nm.acis_allreduce(n, 8) + t_halo + APP_COMPUTE["MG"]
    rows.append((f"fig6_MG_n{n}", ta * 1e6, f"speedup={tb / ta:.2f}"))

    # LU / SP: p2p dominated
    t_p2p = 40 * (nm.PAPER.mpi_overhead + 65536 / nm.PAPER.bw)
    for app in ("LU", "SP"):
        tb = t_p2p + 4 * nm.mpi_allreduce(n, 40) + APP_COMPUTE[app]
        ta = t_p2p + 4 * nm.acis_allreduce(n, 40) + APP_COMPUTE[app]
        rows.append((f"fig6_{app}_n{n}", ta * 1e6,
                     f"speedup={tb / ta:.2f}"))

    # miniFE: CG iteration = 2 dots (8 B allreduce) + matvec halo
    t_halo = 2 * (nm.PAPER.mpi_overhead + 16384 / nm.PAPER.bw)
    tb = 2 * nm.mpi_allreduce(n, 8) + t_halo + APP_COMPUTE["miniFE"]
    ta = 2 * nm.acis_allreduce(n, 8) + t_halo + APP_COMPUTE["miniFE"]
    rows.append((f"fig6_miniFE_n{n}", ta * 1e6, f"speedup={tb / ta:.2f}"))
    return rows


# ---------------------------------------------------------------------------
# real JAX measurements (8 host devices): fused vs unfused on the engine
# ---------------------------------------------------------------------------

def _time_fn(fn, *args, iters: int = 5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        out = out[0] if isinstance(out, tuple) else out
        out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def jax_measurements() -> list[tuple]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core import fused
    from repro.core.lookaside import gcn_aggregate

    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def smap(fn, in_specs, out_specs):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

    rows = []
    rng = np.random.default_rng(0)

    # Fig 5 real: fused allgather_op_allgather vs baseline
    x = jnp.asarray(rng.standard_normal((8 * 65536,)).astype(np.float32))
    f_fused = smap(lambda v: fused.allgather_op_allgather(v, "data"),
                   P("data"), P(None))
    f_base = smap(lambda v: fused.allgather_op_allgather_baseline(v, "data"),
                  P("data"), P(None))
    tf, tb = _time_fn(f_fused, x), _time_fn(f_base, x)
    rows.append(("jax_fig5_fused_ag_op_ag", tf * 1e6,
                 f"speedup={tb / tf:.2f}"))

    # IS real: fused AR+A2A vs sequential
    hist = jnp.asarray(rng.integers(0, 9, (8, 1024)).astype(np.float32))
    keys = jnp.asarray(rng.standard_normal((8, 8 * 8192)).astype(np.float32))
    sp = (P("data", None), P("data", None))
    def _wrap(fn):
        def inner(h, k):
            hh, kk = fn(h[0], k[0], "data")
            return hh[None], kk[None]
        return inner

    g_fused = smap(_wrap(fused.fused_allreduce_alltoall), sp, sp)
    g_base = smap(_wrap(fused.allreduce_alltoall_baseline), sp, sp)
    tf, tb = _time_fn(g_fused, hist, keys), _time_fn(g_base, hist, keys)
    rows.append(("jax_fig6_IS_fused_ar_a2a", tf * 1e6,
                 f"speedup={tb / tf:.2f}"))

    # Fig 4 real: in-network GCN aggregation vs allgather+spmm
    n, rows_l, d = 8, 256, 64
    adj = (rng.random((8 * rows_l, 8 * rows_l)) < 0.05).astype(np.float32)
    adj_blocks = adj.reshape(8, rows_l, 8, rows_l).transpose(0, 2, 1, 3)
    feats = rng.standard_normal((8, rows_l, d)).astype(np.float32)
    in_sp = (P("data", None, None, None), P("data", None, None))
    h_net = smap(lambda a, xx: gcn_aggregate(a[0], xx[0], "data",
                                             in_network=True)[None],
                 in_sp, P("data", None, None))
    h_base = smap(lambda a, xx: gcn_aggregate(a[0], xx[0], "data",
                                              in_network=False)[None],
                  in_sp, P("data", None, None))
    tf = _time_fn(h_net, jnp.asarray(adj_blocks), jnp.asarray(feats))
    tb = _time_fn(h_base, jnp.asarray(adj_blocks), jnp.asarray(feats))
    rows.append(("jax_fig4_gcn_innetwork", tf * 1e6,
                 f"speedup={tb / tf:.2f}"))

    # Type 2/3 compression: int8 shared-scale vs f32 ring allreduce bytes
    from repro.core.lookaside import shared_scale_quant_all_reduce
    from repro.core import collectives
    from repro.core.types import ADD
    g = jnp.asarray(rng.standard_normal((8, 1 << 20)).astype(np.float32))
    q_fn = smap(lambda v: shared_scale_quant_all_reduce(v[0], "data")[0][None],
                P("data", None), P("data", None))
    f_fn = smap(lambda v: collectives.all_reduce(v[0], "data", ADD)[None],
                P("data", None), P("data", None))
    tq, tf32 = _time_fn(q_fn, g), _time_fn(f_fn, g)
    rows.append(("jax_type2_int8_allreduce", tq * 1e6,
                 f"wire_ratio=0.5,time_vs_f32={tf32 / tq:.2f}"))
    return rows
