"""Pallas bulk data path microbenchmarks.

Three A/Bs, one per tentpole piece:

* **batched launch** — k independent same-axis allreduces priced as k
  separate rings vs one ring over the chunk-aligned stacked buffer
  (:func:`repro.core.netmodel.batched_ring_times`), plus the measured
  jit wall-clock of both lowerings on the 8-device host mesh;
* **RS/AG bucketing** — per-leaf reduce-scatter / all-gather vs the
  single bucket collective
  (:func:`repro.core.netmodel.bucketed_collective_times`);
* **fused pack** — the arena pack as one aliased Pallas launch
  (interpret mode on CPU) vs the per-part dynamic_update_slice loop,
  measured wall-clock.

The analytic rows are deterministic and CI-gated through
``benchmarks/check_regression.py``; the ``jax_*`` wall-clock rows are
recorded-but-not-gated like every other real measurement.  On CPU the
fused-pack kernel runs under the Pallas interpreter, so its wall-clock
row documents the correctness vehicle, not silicon performance.
"""

from __future__ import annotations

import time

import numpy as np

AXIS = 8                       # host devices on the benchmark mesh
K = 8                          # independent rings merged per launch
RING_KB = 32                   # per-ring payload


def _median_us(run, iters: int = 12) -> float:
    run()                      # warm / compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def analytic_rows() -> list[tuple]:
    from repro.core import netmodel

    p = netmodel.PAPER
    out = []

    # k same-axis rings, ragged payloads spanning the small-bucket regime
    sizes = [(1 << 14) + 1024 * i for i in range(K)]
    sep, bat = netmodel.batched_ring_times(AXIS, sizes, p)
    out.append((f"ring_batched_launch_k{K}", bat * 1e6,
                f"speedup={sep / bat:.2f}"
                f",separate_us={sep * 1e6:.2f},n={AXIS}"))

    # per-leaf RS / AG vs one bucket collective, 16-leaf ragged tail
    rng = np.random.default_rng(7)
    leaf_sizes = [int(rng.integers(1 << 8, 1 << 13)) * AXIS
                  for _ in range(16)]
    for kind, tag in (("reduce_scatter", "rs"), ("allgather", "ag")):
        sep, tot = netmodel.bucketed_collective_times(
            kind, AXIS, leaf_sizes, p)
        out.append((f"ring_bucket_{tag}16", tot * 1e6,
                    f"speedup={sep / tot:.2f}"
                    f",per_leaf_us={sep * 1e6:.2f},n={AXIS}"))
    return out


def _ring_wallclock_rows() -> list[tuple]:
    """Measured: K independent same-axis rings, per-program dispatch vs
    one batched launch (identical bytes; the delta is launch count)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core import make_engine, tracing

    mesh = jax.make_mesh((AXIS,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sizes = [RING_KB * 256 + 64 * i for i in range(K)]   # f32 elements
    avals = tuple(jax.ShapeDtypeStruct((s,), jnp.float32) for s in sizes)
    rng = np.random.default_rng(0)
    xs = [jnp.asarray(rng.standard_normal((AXIS, s)).astype(np.float32))
          for s in sizes]

    def prog(*gs):
        return tuple(tracing.reduce(g, axis="data") for g in gs)

    spec = P("data", None)
    runs = {}
    for br in (False, True):
        eng = make_engine("acis", batch_rings=br, bucket_bytes=0)
        c = eng.compile(tracing.trace(prog, num_inputs=K),
                        in_avals=avals, axis_size=AXIS)

        def body(*ls, _c=c):
            return tuple(o[None] for o in _c(*[l[0] for l in ls]))

        fn = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(spec,) * K,
                                   out_specs=(spec,) * K, check_vma=False))
        runs[br] = (lambda _fn=fn: jax.block_until_ready(_fn(*xs)), c)

    t_per = _median_us(runs[False][0])
    t_bat = _median_us(runs[True][0])
    kinds = runs[True][1].stage_kinds()
    return [
        (f"jax_ring_batched_k{K}_per_program", t_per,
         f"collectives={K}"),
        (f"jax_ring_batched_k{K}_batched", t_bat,
         f"speedup={t_per / t_bat:.2f}"
         f",batched_stages={kinds.count('batched_allreduce')}"),
    ]


def _pack_wallclock_rows() -> list[tuple]:
    """Measured: the bucket pack into a persistent arena — per-part
    dynamic_update_slice loop vs one aliased pack_combine launch
    (Pallas interpreter on CPU)."""
    import jax
    import jax.numpy as jnp

    from repro.core import switchops

    switchops.load_kernels()
    rng = np.random.default_rng(1)
    part_sizes = [int(rng.integers(1 << 10, 1 << 14)) for _ in range(12)]
    arena = jnp.zeros((sum(part_sizes),), jnp.float32)
    parts = [jnp.asarray(rng.standard_normal((s,)).astype(np.float32))
             for s in part_sizes]
    op = switchops.get("pack_combine")

    @jax.jit
    def unfused(a, *ps):
        off = 0
        for x in ps:
            a = jax.lax.dynamic_update_slice(a, x, (off,))
            off += x.shape[0]
        return a

    fused = jax.jit(lambda a, *ps: op(a, *ps, use_kernel=True))

    t_loop = _median_us(
        lambda: jax.block_until_ready(unfused(arena, *parts)))
    t_fused = _median_us(
        lambda: jax.block_until_ready(fused(arena, *parts)))
    return [
        ("jax_ring_pack_unfused", t_loop,
         f"parts={len(part_sizes)}"),
        ("jax_ring_pack_fused", t_fused,
         f"speedup={t_loop / t_fused:.2f},interpret=cpu"),
    ]


def rows() -> list[tuple]:
    return analytic_rows() + _ring_wallclock_rows() + _pack_wallclock_rows()
