import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# match benchmarks.run — the wall-clock A/B shards over 8 host devices

"""Autotuning benchmark: the repro.tune loop measured end to end.

Record a simulated trace of the 64-leaf ragged gradient sync, replay it
(self-replay fidelity), fit the simulator's link parameters back out of
it (fit recovery), search the tunable config space with replay as the
objective (tuned vs default ``program_time``), and cross-check the
replayed score of the tuned plan against an actual simulator rerun
(replay-vs-rerun agreement).  Everything except the ``jax_*`` wall-clock
rows is deterministic — CI gates them via ``BENCH_tune.json`` +
``benchmarks/baseline_tune.json``.

Two workloads from :mod:`benchmarks.execplan`: the *mixed* ragged
64-leaf pytree (big matmul leaves + small tail; the model-derived
default bucket is already optimal there — the search must find nothing
and say so) and the *tail* all-small 64-leaf pytree (dispatch-bound;
the regime where the search beats the default bucket size).
"""

import dataclasses

import numpy as np

from benchmarks.execplan import (AXIS_SIZE, _collectives, _ragged_sizes,
                                 _sync_program, _tail_sizes)

N_LEAVES = 64


def _build(sizes, axis_sizes):
    """Candidate builder: compile the ragged sync under one config."""
    from repro.core import make_engine

    def build(cfg):
        eng = make_engine("acis")
        eng.config = cfg
        return _sync_program(sizes, eng, axis_sizes)
    return build


def _sim_inputs(sizes, grid, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(grid + (s,)).astype(np.float32)
            for s in sizes]


def rows() -> list[tuple]:
    """CSV rows: self-replay fidelity, fit recovery, search outcome,
    replay-vs-rerun agreement, and the measured wall-clock A/B of the
    tuned config."""
    import repro.tune as tune
    from repro.cgra.simulate import SwitchSim
    from repro.core import make_engine

    out = []

    # -- self-replay fidelity (acceptance: within 5%) ----------------------
    sizes = _ragged_sizes()
    eng = make_engine("acis")
    default = _sync_program(sizes, eng, {"data": 4})
    ins = _sim_inputs(sizes, (4,))
    _, trace, report = tune.record_sim(
        default, SwitchSim(default.topology), *ins)
    r_self = tune.replay(default.plan, trace, default.topology)
    out.append((
        f"tune_selfreplay_sync{N_LEAVES}_ratio",
        r_self.t_end / report.t_end,
        f"replay_us={r_self.t_end * 1e6:.2f}"
        f",sim_us={report.t_end * 1e6:.2f}"
        f",matched={r_self.matched}/{len(default.plan.stages)}"))

    # -- fit recovery: perturbed sim link params come back out -------------
    fit_sizes = [4096, 65536, 131072, 524288, 8192, 262144]
    per_leaf = _sync_program(
        fit_sizes, make_engine("acis", bucket_bytes=0), {"data": 4})
    sim = SwitchSim(per_leaf.topology)
    true = dataclasses.replace(sim.nets["data"],
                               bw=sim.nets["data"].bw * 0.5,
                               fpga_link=sim.nets["data"].fpga_link * 2.0)
    sim.nets["data"] = true
    _, fit_trace, _ = tune.record_sim(
        per_leaf, sim, *_sim_inputs(fit_sizes, (4,)))
    fit = tune.fit_net_params(
        [(per_leaf.plan, per_leaf.topology, fit_trace)], tiers=("ici",))
    got = fit.tiers["ici"]
    out.append((
        "tune_fit_bw_ratio", got.bw / true.bw,
        f"fitted_gbps={got.bw / 1e9:.2f},true_gbps={true.bw / 1e9:.2f}"
        f",link_ratio={got.fpga_link / true.fpga_link:.4f}"
        f",residual={fit.residual:.2e},stages={fit.n_stages}"))

    # -- search: tuned vs default program_time on the ragged tail ----------
    base = make_engine("acis").config
    build = _build(_tail_sizes(), {"data": AXIS_SIZE})
    res = tune.search(build, base=base)
    tuned_cfg = dataclasses.replace(base, **res.overrides)
    tuned = build(tuned_cfg)
    dflt = build(base)
    t_tuned = tuned.program_time()
    t_dflt = dflt.program_time()
    out.append((
        f"tune_search_sync{N_LEAVES}_tail", t_tuned * 1e6,
        f"speedup={t_dflt / t_tuned:.4f}"
        f",default_us={t_dflt * 1e6:.2f}"
        f",overrides={'|'.join(f'{k}:{v}' for k, v in sorted(res.overrides.items())) or 'none'}"
        f",evals={res.n_evals}"
        f",collectives={_collectives(tuned)}v{_collectives(dflt)}"))

    # -- replay-vs-rerun: the searched plan's replayed score against an
    # actual simulator rerun of that plan (the objective is honest) -------
    tail = _tail_sizes()
    tail_ins = _sim_inputs(tail, (AXIS_SIZE,))
    _, tail_trace, _ = tune.record_sim(
        dflt, SwitchSim(dflt.topology), *tail_ins)
    r_tuned = tune.replay(tuned.plan, tail_trace, tuned.topology,
                          overlapped=tuned_cfg.overlap_dispatch)
    _, rerun = SwitchSim(tuned.topology).run(tuned, *tail_ins)
    out.append((
        f"tune_replay_vs_rerun_sync{N_LEAVES}_ratio",
        r_tuned.t_end / rerun.t_end,
        f"replay_us={r_tuned.t_end * 1e6:.2f}"
        f",rerun_us={rerun.t_end * 1e6:.2f}"
        f",matched={r_tuned.matched},modeled={r_tuned.modeled}"))

    out += wallclock_rows(tuned_cfg)
    return out


def wallclock_rows(tuned_cfg) -> list[tuple]:
    """Measured jit wall-clock of the tail sync under the searched config
    vs the default, interleaved medians (``jax_*``: recorded, not
    gated)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    import repro.tune as tune
    from repro.core import make_engine

    sizes = _tail_sizes()
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    spec = P("data", None)
    rng = np.random.default_rng(0)
    leaves = [jnp.asarray(rng.standard_normal((8, s)).astype(np.float32))
              for s in sizes]

    def runner(cfg):
        eng = make_engine("acis")
        eng.config = cfg
        c = _sync_program(sizes, eng, {"data": 8})

        def body(*ls):
            outs = c(*[l[0] for l in ls])
            return tuple(o[None] for o in outs)

        fn = jax.jit(jax.shard_map(
            body, mesh=mesh, in_specs=(spec,) * len(sizes),
            out_specs=(spec,) * len(sizes), check_vma=False))

        def run():
            jax.block_until_ready(fn(*leaves))
        return run

    meds = tune.interleaved_medians(
        {"default": runner(make_engine("acis").config),
         "tuned": runner(tuned_cfg)}, iters=6)
    return [
        (f"jax_tune_sync{N_LEAVES}_wallclock_default",
         meds["default"] * 1e6, ""),
        (f"jax_tune_sync{N_LEAVES}_wallclock_tuned",
         meds["tuned"] * 1e6,
         f"speedup={meds['default'] / meds['tuned']:.2f}"),
    ]


def record(computed_rows: list | None = None) -> dict:
    """BENCH_tune.json payload.

    Ratio rows (``*_ratio``) are folded symmetrically — ``max(r, 1/r)``
    — so the lower-is-better regression gate catches replay drifting
    high *or* low against a baseline of 1.0; rows carrying a
    ``speedup=`` derived metric also record ``name.speedup``.  Both are
    what ``check_regression.py`` gates against
    ``benchmarks/baseline_tune.json`` (``jax_*`` rows ride along
    ungated).
    """
    out: dict = {}
    for name, val, derived in (computed_rows if computed_rows is not None
                               else rows()):
        val = float(val)
        if name.endswith("_ratio") and val > 0:
            val = max(val, 1.0 / val)
        out[name] = round(val, 6)
        for part in str(derived).split(","):
            k, _, v = part.partition("=")
            if k == "speedup":
                try:
                    out[f"{name}.speedup"] = round(float(v), 4)
                except ValueError:
                    pass
    return out


if __name__ == "__main__":
    print("name,value,derived")
    for name, val, derived in rows():
        print(f"{name},{val},{derived}")
