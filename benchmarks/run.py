import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# 8 host devices for the real-engine measurements (process-local; the
# dry-run sets its own 512 and tests their own 8 — nothing shared).

"""Benchmark runner — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--json]

Prints ``name,us_per_call,derived`` CSV.  Default mode prints the summary
rows (per-figure means + the real-JAX engine measurements); ``--full``
additionally dumps every (collective × nodes × size) emulator point.
``--json`` additionally writes ``BENCH_netmodel.json`` (name →
us_per_call), ``BENCH_cgra.json`` (per-benchmark simulated vs
analytic switch latency from the dataplane simulator),
``BENCH_tune.json`` (autotuning-loop fidelity + search outcome),
``BENCH_obs.json`` (instrumentation overhead + drift-watchdog
precision), ``BENCH_serve.json`` (compiled serving data path:
decode-program vs per-op-ring switch time, fused MoE combine, and the
measured compiled-vs-plain decode wall-clock),
``BENCH_elastic.json`` (bounded-staleness sync: masked overhead at zero
faults, recompile reuse on membership change, and the dead-rank
degradation curve), ``BENCH_sync64.trace.json`` (the 64-leaf sync
Perfetto timeline) and ``BENCH_faults.trace.json`` (the worst-case
faulted sync timeline) so CI can record the trajectories as artifacts.
"""

import json
import sys

JSON_PATH = "BENCH_netmodel.json"
CGRA_JSON_PATH = "BENCH_cgra.json"
TUNE_JSON_PATH = "BENCH_tune.json"
OBS_JSON_PATH = "BENCH_obs.json"
SERVE_JSON_PATH = "BENCH_serve.json"
ELASTIC_JSON_PATH = "BENCH_elastic.json"


def main() -> None:
    full = "--full" in sys.argv
    from benchmarks import figures

    rows: list[tuple] = []

    # Fig 3 — OSU collectives
    s3 = figures.fig3_summary()
    for name, sp in s3.items():
        rows.append((f"fig3_{name}_mean", 0.0, f"mean_speedup={sp:.2f}"))
    if full:
        rows += figures.fig3_osu()

    # Fig 5 — fused Allgather_op_Allgather (paper: avg 1.98x)
    rows.append(("fig5_mean", 0.0,
                 f"mean_speedup={figures.fig5_mean_speedup():.2f}"
                 f",paper=1.98"))
    rows += figures.fig5_emulated() if full else []

    # Fig 4 — GCN (paper: avg 3.4x at 24 nodes)
    rows.append(("fig4_mean", 0.0,
                 f"mean_speedup={figures.fig4_mean_speedup():.2f}"
                 f",paper=3.4"))
    rows += figures.fig4_gcn()

    # Fig 6 — NPB + miniFE proxies
    rows += figures.fig6_npb(128)
    rows += figures.fig6_npb(64)

    # real engine measurements (8 host devices)
    rows += figures.jax_measurements()

    # dataplane simulator vs analytic model, per compiled benchmark
    from benchmarks import cgra
    cgra_rows = cgra.rows()
    rows += cgra_rows

    # execution planning: bucketized vs per-leaf gradient sync, and the
    # simulated vs analytic overlap cross-check
    from benchmarks import execplan
    rows += execplan.rows()

    # Pallas bulk data path: batched ring launches, RS/AG bucketing,
    # fused arena pack
    from benchmarks import ring
    rows += ring.rows()

    # autotuning loop: self-replay fidelity, fit recovery, tuned vs
    # default search, replay-vs-rerun agreement
    from benchmarks import tune
    tune_rows = tune.rows()
    rows += tune_rows

    # observability: instrumentation overhead bounds, timeline export,
    # drift-watchdog precision
    from benchmarks import obs
    obs_rows = obs.rows()
    rows += obs_rows

    # compiled serving data path: decode programs vs per-op rings, fused
    # MoE combine, engine throughput over the shared program cache
    from benchmarks import serve
    serve_rows = serve.rows()
    rows += serve_rows

    # elastic fault tolerance: masked-sync overhead gate, topology-change
    # recompile reuse, simulated dead-rank degradation curve
    from benchmarks import elastic
    elastic_rows = elastic.rows()
    rows += elastic_rows

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")

    # derived keys that are *measurements* (constants like paper=…,
    # wire_ratio=… would otherwise pollute the trajectory artifact)
    METRIC_KEYS = {"speedup", "mean_speedup", "time_vs_f32"}

    if "--json" in sys.argv:
        record: dict = {}
        for name, us, derived in rows:
            # summary rows carry their real metric (mean_speedup=…) in the
            # derived column with a placeholder us of 0.0 — record the
            # metric and skip the fake measurement
            n_metrics = 0
            for part in str(derived).split(","):
                k, _, v = part.partition("=")
                if k not in METRIC_KEYS:
                    continue
                try:
                    record[f"{name}.{k}"] = float(v)
                    n_metrics += 1
                except ValueError:
                    pass
            if us or not n_metrics:
                record[name] = round(us, 3)
        with open(JSON_PATH, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {JSON_PATH}", file=sys.stderr)

        with open(CGRA_JSON_PATH, "w") as f:
            json.dump(cgra.record(cgra_rows), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {CGRA_JSON_PATH}", file=sys.stderr)

        with open(TUNE_JSON_PATH, "w") as f:
            json.dump(tune.record(tune_rows), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {TUNE_JSON_PATH}", file=sys.stderr)

        with open(OBS_JSON_PATH, "w") as f:
            json.dump(obs.record(obs_rows), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {OBS_JSON_PATH}", file=sys.stderr)

        with open(SERVE_JSON_PATH, "w") as f:
            json.dump(serve.record(serve_rows), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {SERVE_JSON_PATH}", file=sys.stderr)

        with open(ELASTIC_JSON_PATH, "w") as f:
            json.dump(elastic.record(elastic_rows), f, indent=2,
                      sort_keys=True)
            f.write("\n")
        print(f"wrote {ELASTIC_JSON_PATH}", file=sys.stderr)

        # the Perfetto-loadable timelines: the 64-leaf sync and the
        # worst-case faulted sync, uploaded next to the BENCH_*.json
        # trajectories
        print(f"wrote {obs.write_trace()}", file=sys.stderr)
        print(f"wrote {elastic.write_trace()}", file=sys.stderr)


if __name__ == "__main__":
    main()
