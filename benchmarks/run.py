import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# 8 host devices for the real-engine measurements (process-local; the
# dry-run sets its own 512 and tests their own 8 — nothing shared).

"""Benchmark runner — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV.  Default mode prints the summary
rows (per-figure means + the real-JAX engine measurements); ``--full``
additionally dumps every (collective × nodes × size) emulator point.
"""

import sys


def main() -> None:
    full = "--full" in sys.argv
    from benchmarks import figures

    rows: list[tuple] = []

    # Fig 3 — OSU collectives
    s3 = figures.fig3_summary()
    for name, sp in s3.items():
        rows.append((f"fig3_{name}_mean", 0.0, f"mean_speedup={sp:.2f}"))
    if full:
        rows += figures.fig3_osu()

    # Fig 5 — fused Allgather_op_Allgather (paper: avg 1.98x)
    rows.append(("fig5_mean", 0.0,
                 f"mean_speedup={figures.fig5_mean_speedup():.2f}"
                 f",paper=1.98"))
    rows += figures.fig5_emulated() if full else []

    # Fig 4 — GCN (paper: avg 3.4x at 24 nodes)
    rows.append(("fig4_mean", 0.0,
                 f"mean_speedup={figures.fig4_mean_speedup():.2f}"
                 f",paper=3.4"))
    rows += figures.fig4_gcn()

    # Fig 6 — NPB + miniFE proxies
    rows += figures.fig6_npb(128)
    rows += figures.fig6_npb(64)

    # real engine measurements (8 host devices)
    rows += figures.jax_measurements()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
