"""Simulated vs analytic switch latency (BENCH_cgra.json).

Each point compiles a representative switch program through the full
pass pipeline (so every stage carries a CGRA placement or an explicit
host fallback), executes it on the dataplane simulator
(:mod:`repro.cgra.simulate` — no mesh, no shard_map, pure in-process),
and records the simulated end-to-end latency next to the
:mod:`repro.core.netmodel` analytic prediction.  CI uploads the JSON so
the two models can be tracked against each other over time.
"""

from __future__ import annotations

import numpy as np


def _points():
    """Yield (name, compiled, topology_sizes, inputs)."""
    import jax
    import jax.numpy as jnp

    from repro import core as acis
    from repro.core import make_engine

    AV = jax.ShapeDtypeStruct
    rng = np.random.default_rng(0)

    # Fig. 5 fused AG∘scan∘AG — one in-network traversal, 8 ranks, 8 KB
    eng = make_engine("acis")
    c = eng.compile(
        lambda x: acis.all_gather(acis.scan(acis.all_gather(x))),
        in_avals=(AV((2048,), jnp.float32),), axis_size=8,
        axis_name="data")
    yield ("fig5_fused_scanAG_8r_8KB", c, eng.topology(axis_size=8),
           (rng.standard_normal((8, 2048)).astype(np.float32),))

    # MapReduce: square fused ahead of the AR schedule — 64 KB
    c = eng.compile(
        lambda x: acis.reduce(acis.map(jnp.square, x, name="sq")),
        in_avals=(AV((16384,), jnp.float32),), axis_size=8)
    yield ("mapreduce_sq_8r_64KB", c, eng.topology(axis_size=8),
           (rng.standard_normal((8, 16384)).astype(np.float32),))

    # NAS-IS: AR + A2A pair fused onto one ring traversal
    c = eng.compile(
        lambda h, k: (acis.reduce(h), acis.all_to_all(k)),
        in_avals=(AV((1024,), jnp.float32), AV((8192,), jnp.float32)),
        axis_size=8)
    yield ("nas_is_fusedARA2A_8r", c, eng.topology(axis_size=8),
           (rng.standard_normal((8, 1024)).astype(np.float32),
            rng.standard_normal((8, 8192)).astype(np.float32)))

    # Hierarchical compressed sync: int8 codec on the thin inter-pod hop
    engh = make_engine("acis_hierarchical_compressed", inner_axis="data",
                       outer_axis="pod")
    sizes = {"data": 4, "pod": 2}
    c = engh.compile(lambda x: acis.reduce(x, axis="auto"),
                     in_avals=(AV((16384,), jnp.float32),),
                     axis_size=sizes)
    yield ("hier_sync_int8_2x4_64KB", c, engh.topology(axis_size=sizes),
           (rng.standard_normal((4, 2, 16384)).astype(np.float32),))

    # Error-feedback look-aside sync (shared-scale int8 compressor)
    engc = make_engine("acis_compressed")
    c = engc.compile(lambda x: acis.ef_reduce(x, axis="data")[0],
                     in_avals=(AV((16384,), jnp.float32),), axis_size=8)
    yield ("ef_sync_int8_8r_64KB", c, engc.topology(axis_size=8),
           (rng.standard_normal((8, 16384)).astype(np.float32),))

    # Host-fallback path: top-k sparsifier does not fit the CGRA
    c = engc.compile(
        lambda x: acis.ef_reduce(x, axis="data", compressor="topk",
                                 topk_ratio=0.01)[0],
        in_avals=(AV((16384,), jnp.float32),), axis_size=8)
    yield ("ef_sync_topk_fallback_8r_64KB", c, engc.topology(axis_size=8),
           (rng.standard_normal((8, 16384)).astype(np.float32),))


def rows() -> list[tuple]:
    """CSV rows: (name, simulated_us, 'analytic_us=…,fallbacks=…')."""
    from repro.cgra.simulate import SwitchSim

    out = []
    for name, compiled, topo, inputs in _points():
        sim = SwitchSim(topo)
        _, report = sim.run(compiled, *inputs)
        n_fb = sum(1 for s in compiled.stages
                   if s.placement is not None and not s.placement.fits)
        out.append((f"cgra_{name}", report.t_sim * 1e6,
                    f"analytic_us={report.t_model * 1e6:.2f}"
                    f",stages={len(report.stages)}"
                    f",fallbacks={n_fb}"))
    return out


def record(computed_rows: list | None = None) -> dict:
    """BENCH_cgra.json payload: simulated vs analytic per benchmark.

    Pass rows already computed by :func:`rows` to avoid recompiling and
    re-simulating the whole benchmark set.
    """
    out: dict = {}
    for name, sim_us, derived in (computed_rows if computed_rows
                                  is not None else rows()):
        out[f"{name}.simulated_us"] = round(sim_us, 3)
        for part in derived.split(","):
            k, _, v = part.partition("=")
            if k == "analytic_us":
                out[f"{name}.analytic_us"] = round(float(v), 3)
    return out
