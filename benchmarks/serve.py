"""Compiled serving data path benchmarks.

The launch-bound serving regime: a many-layer tensor-parallel decode tick
is 2·L dependent all-reduces of tiny [B, 1, D] partials — per-op ring
launches are pure hop latency, exactly where the compiled path's
latency-optimal log-step schedule (and PR 7's launch amortization) pays.

Three A/Bs:

* **decode program** (analytic, CI-gated) — one decode tick's switch time
  with the compiled schedule vs the per-op bandwidth rings the uncompiled
  ``DirectTPHook`` issues (``latency_optimal_below=0`` prices those);
* **MoE fused combine** (analytic, CI-gated) — the Type-4
  ``allreduce+alltoall`` stage (shared-expert all-reduce fused into the
  expert combine) vs issuing the pair separately;
* **decode wall-clock** (measured on the 8-device host mesh) — the same
  jitted TP decode through the compiled hook vs the direct-ring hook vs
  the XLA baseline; the compiled/direct speedup is gated
  (``serve_decode_wallclock.speedup``), the raw ``jax_*`` latencies ride
  along ungated like every other real measurement.

Plus a full ``ServeEngine`` continuous-batching run over the compiled
transport (throughput trajectory + shared-program-cache hit stats).
"""

from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import numpy as np

AXIS = 8                       # tp width on the benchmark host mesh
LAYERS = 16                    # the launch-bound regime: many thin layers
SLOTS = 4
SEQ = 32
MOE_TP = 2                     # qwen2 smoke has n_kv_heads=4, n_experts=4


def _median_us(run, iters: int = 10) -> float:
    run()                      # warm / compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def _bench_cfg():
    from repro.models.config import ModelConfig
    # thin layers (2-matmul relu2 FFN, short cache) so per-layer compute
    # stays small next to the 2·L sequential all-reduces — the regime the
    # compiled path targets
    return ModelConfig(
        name="serve-bench", family="dense",
        n_layers=LAYERS, d_model=64, n_heads=8, n_kv_heads=8,
        d_ff=128, vocab=256, activation="relu2", max_seq=SEQ,
        remat="none")


def _collectives(cfg, tp, **overrides):
    from repro.core.api import CollectiveConfig
    from repro.serve.collectives import ServeCollectives, SwitchProgramCache
    return ServeCollectives(
        cfg, tp, cache=SwitchProgramCache(),
        config=CollectiveConfig(backend="acis", **overrides))


def analytic_rows() -> list[tuple]:
    import jax
    import jax.numpy as jnp

    cfg = _bench_cfg()
    # compiled: the scheduler picks the log-step latency schedule for the
    # sub-crossover decode payloads; direct: every hook call is its own
    # bandwidth ring (latency_optimal_below=0 prices exactly that)
    sc_fast = _collectives(cfg, AXIS, batch_rings=True)
    sc_ring = _collectives(cfg, AXIS, latency_optimal_below=0)
    t_fast = sc_fast.decode_comm_time(SLOTS)
    t_ring = sc_ring.decode_comm_time(SLOTS)
    out = [("serve_decode_program", t_fast * 1e6,
            f"speedup={t_ring / t_fast:.2f}"
            f",ring_us={t_ring * 1e6:.2f},layers={LAYERS},n={AXIS}")]

    # MoE combine: the fused Type-4 stage vs separate AR + A2A programs
    from repro import configs
    mcfg = configs.get_smoke("qwen2-moe-a2-7b")
    sc = _collectives(mcfg, MOE_TP)
    progs = {name: prog for name, prog, _ in sc.decode_programs(SLOTS)}
    fused = progs["serve_moe_combine"].program_time()
    d, e = mcfg.d_model, mcfg.moe.n_experts
    sds = jax.ShapeDtypeStruct
    sep = sc.program("serve_tp_allreduce", sc._trace_allreduce,
                     (sds((1, SLOTS, d), jnp.bfloat16),)).program_time() \
        + sc.program("serve_moe_alltoall", sc._trace_alltoall,
                     (sds((e, SLOTS, d), jnp.bfloat16),)).program_time()
    out.append(("serve_moe_combine_fused", fused * 1e6,
                f"speedup={sep / fused:.2f},separate_us={sep * 1e6:.2f}"
                f",n={MOE_TP}"))
    return out


def wallclock_rows() -> list[tuple]:
    import jax
    import jax.numpy as jnp

    from repro.models.model import Model

    cfg = _bench_cfg()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(SLOTS, SEQ)
    tok = jnp.arange(SLOTS, dtype=jnp.int32) % cfg.vocab
    idx = jnp.full(SLOTS, 3, jnp.int32)

    def mk(mode, **overrides):
        sc = _collectives(cfg, AXIS, **overrides)
        dec = sc.decode_fn(params, cache, mode=mode, donate=False)
        return lambda: jax.block_until_ready(dec(params, tok, cache, idx))

    direct = mk("direct")
    compiled = mk("compiled", batch_rings=True)
    xla = mk("xla")
    for f in (direct, compiled, xla):
        f(); f()                       # warm / compile
    # interleave the three transports per iteration so machine-load bursts
    # hit all of them alike; the gated speedup is the median of per-pair
    # ratios (load-robust), the reported latencies are the per-mode minima
    td, tc, tx = [], [], []
    for _ in range(12):
        for f, acc in ((direct, td), (compiled, tc), (xla, tx)):
            t0 = time.perf_counter()
            f()
            acc.append(time.perf_counter() - t0)
    td, tc, tx = np.array(td), np.array(tc), np.array(tx)
    speedup = float(np.median(td / tc))
    return [
        ("jax_serve_decode_plain", float(td.min()) * 1e6,
         f"transport=direct_rings,layers={LAYERS},n={AXIS}"),
        ("jax_serve_decode_compiled", float(tc.min()) * 1e6,
         f"speedup={speedup:.2f},transport=switch_programs"),
        ("jax_serve_decode_xla", float(tx.min()) * 1e6, "transport=xla_psum"),
        # the gated ratio (measured, but an A/B of two lowerings of the
        # same program on the same host — stable, unlike raw latencies)
        ("serve_decode_wallclock", 0.0, f"speedup={speedup:.2f}"),
    ]


def engine_rows() -> list[tuple]:
    """Continuous batching end-to-end over the compiled transport."""
    import jax

    from repro.models.model import Model
    from repro.serve.collectives import ServeCollectives, SwitchProgramCache
    from repro.serve.engine import Request, ServeEngine

    cfg = _bench_cfg()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    shared = SwitchProgramCache()
    rng = np.random.default_rng(0)

    def replica():
        sc = ServeCollectives(cfg, AXIS, cache=shared)
        eng = ServeEngine(model, params, slots=SLOTS, max_seq=SEQ,
                          collectives=sc)
        for i in range(6):
            eng.submit(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                max_new_tokens=8))
        t0 = time.perf_counter()
        done = eng.run_to_completion()
        dt = time.perf_counter() - t0
        return sum(len(c.tokens) for c in done) / dt, eng

    toks_per_s, eng = replica()
    misses_first = shared.stats()["misses"]
    toks_per_s2, _ = replica()          # second replica: all cache hits
    extra = shared.stats()["misses"] - misses_first
    tick = eng.tick_time_estimate() or 0.0
    return [
        ("jax_serve_engine_tick", tick * 1e6,
         f"toks_per_s={max(toks_per_s, toks_per_s2):.1f}"
         f",programs={shared.stats()['programs']}"
         f",replica2_extra_compiles={extra}"),
    ]


def rows() -> list[tuple]:
    return analytic_rows() + wallclock_rows() + engine_rows()


def record(computed_rows: list | None = None) -> dict:
    """BENCH_serve.json payload: row values plus every ``speedup=``
    derived metric as ``name.speedup`` (higher-is-better in the gate).
    Rows with a placeholder 0.0 value record only their metric."""
    out: dict = {}
    for name, val, derived in (computed_rows if computed_rows is not None
                               else rows()):
        n_metrics = 0
        for part in str(derived).split(","):
            k, _, v = part.partition("=")
            if k == "speedup":
                try:
                    out[f"{name}.speedup"] = round(float(v), 4)
                    n_metrics += 1
                except ValueError:
                    pass
        if val or not n_metrics:
            out[name] = round(float(val), 3)
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, val, derived in rows():
        print(f"{name},{val:.2f},{derived}")
