"""Execution-planning benchmark: per-leaf vs bucketized gradient sync.

The compiler's Coalesce pass concatenates per-leaf reductions into
flat-buffer bucket collectives; the ExecutionPlan runtime dispatches
waves with cross-axis overlap and writes bucket packs into persistent
donated arenas.  This module prices both against the analytic
:func:`repro.core.netmodel.program_time` on a ragged many-leaf gradient
pytree (the transformer shape: a few big matmul leaves, a long tail of
small biases/norms), cross-checks the overlap model on the dataplane
simulator, **calibrates** the :data:`repro.core.netmodel.TIER_OVERLAP`
fractions from the simulator's cross-axis points, and measures the real
jit wall-clock of the overlapped+arena runtime against the serial
PR-4-style dispatch — the numbers CI tracks in ``BENCH_netmodel.json``
(the ``jax_*`` wall-clock rows are recorded but not gated; everything
else is deterministic and guarded by ``check_regression.py``).
"""

from __future__ import annotations

import time

import numpy as np

N_LEAVES = 64
AXIS_SIZE = 8
HIER_SIZES = {"data": 4, "pod": 2}


def _ragged_sizes(n_leaves: int = N_LEAVES) -> list[int]:
    """Element counts of a transformer-ish gradient pytree: 1/8 large
    matmul leaves, the rest a ragged small tail (deterministic)."""
    rng = np.random.default_rng(7)
    sizes = []
    for i in range(n_leaves):
        if i % 8 == 0:
            sizes.append(int(rng.integers(1 << 18, 1 << 19)))   # 1-2 MB
        else:
            sizes.append(int(rng.integers(1 << 8, 1 << 13)))    # 1-32 KB
    return sizes


def _sync_program(sizes, engine, axis_sizes, *, shared_mean: bool = True):
    """The traced many-leaf mean-sync.  ``shared_mean=True`` declares the
    per-leaf mean elementwise with one shared fn — the shape Coalesce
    hoists onto the bucket; False reproduces the pre-hoist per-leaf
    emission (a fresh fn per leaf, no elementwise promise)."""
    import jax
    import jax.numpy as jnp

    from repro.core import tracing

    n_total = 1
    for v in axis_sizes.values():
        n_total *= v

    def _mean(y):
        return y / n_total

    def sync(*gs):
        outs = []
        for g in gs:
            r = tracing.reduce(g, axis="auto")
            if shared_mean:
                outs.append(tracing.map(_mean, r, name="mean",
                                        elementwise=True))
            else:
                outs.append(tracing.map(lambda y: y / n_total, r,
                                        name="mean"))
        return tuple(outs)

    prog = tracing.trace(sync, name=f"sync[{len(sizes)}]",
                         num_inputs=len(sizes))
    avals = tuple(jax.ShapeDtypeStruct((s,), jnp.float32) for s in sizes)
    return engine.compile(prog, in_avals=avals, axis_size=axis_sizes)


def _collectives(compiled) -> int:
    return sum(1 for s in compiled.stages
               if s.kind not in ("map", "delivered"))


# ---------------------------------------------------------------------------
# TIER_OVERLAP calibration: fit the per-tier overlap fractions from the
# simulator's overlapped t_end on cross-axis waves
# ---------------------------------------------------------------------------

def _calibration_points():
    """Programs whose single wave holds stages on *different* axes (one
    ici, one dci) — the shape whose cost depends on the overlap
    fractions.  Payloads span the latency- and bandwidth-bound regimes
    of both tiers."""
    import jax
    import jax.numpy as jnp

    from repro import core as acis
    from repro.core import make_engine

    AV = jax.ShapeDtypeStruct
    eng = make_engine("acis", inner_axis="data", outer_axis="pod")
    # the thin dci wire makes the pod stage critical whenever the
    # payloads are comparable (ici exposed); the heavily data-skewed
    # points flip the critical chain so the dci exposure is observable
    for m_data, m_pod in ((1 << 10, 1 << 10), (1 << 13, 1 << 13),
                          (1 << 15, 1 << 15), (1 << 17, 1 << 15),
                          (1 << 15, 1 << 17), (1 << 18, 1 << 18),
                          (1 << 19, 1 << 12), (1 << 19, 1 << 13),
                          (1 << 20, 1 << 14)):
        def prog(x, y):
            return (acis.reduce(x, axis="data"),
                    acis.reduce(y, axis="pod"))

        c = eng.compile(prog,
                        in_avals=(AV((m_data,), jnp.float32),
                                  AV((m_pod,), jnp.float32)),
                        axis_size=dict(HIER_SIZES))
        yield eng, c, (m_data, m_pod)


def calibrate(rng_seed: int = 0):
    """Simulate every calibration point, fit TIER_OVERLAP, and report
    the post-fit envelope.  Returns (fitted, samples, worst_err)."""
    from repro.cgra.simulate import SwitchSim
    from repro.core import netmodel

    rng = np.random.default_rng(rng_seed)
    samples = []
    for eng, c, (m_data, m_pod) in _calibration_points():
        x = rng.standard_normal((4, 2, m_data)).astype(np.float32)
        y = rng.standard_normal((4, 2, m_pod)).astype(np.float32)
        _, report = SwitchSim(
            eng.topology(axis_size=dict(HIER_SIZES))).run(c, x, y)
        samples.append((c.plan, c.topology, report.t_end))
    fitted = netmodel.fit_tier_overlap(samples)
    worst = 0.0
    for plan, topo, t_end in samples:
        t_fit = netmodel.program_time(plan, topo, overlap=fitted)
        worst = max(worst, abs(t_fit - t_end) / t_end)
    return fitted, samples, worst


# ---------------------------------------------------------------------------
# measured wall-clock: overlapped + arena dispatch vs serial PR-4 path
# ---------------------------------------------------------------------------

def _tail_sizes(n_leaves: int = N_LEAVES) -> list[int]:
    """A ragged all-tail 64-leaf pytree (every leaf 1-32 KB, ~2 MB
    total): the dispatch-bound regime where per-kernel and
    per-collective fixed costs dominate over ring byte movement —
    i.e. where the runtime mechanics (hoisted epilogue, donated arenas,
    merged wave dispatch) are what the wall-clock measures."""
    rng = np.random.default_rng(7)
    return [int(rng.integers(1 << 8, 1 << 13)) for _ in range(n_leaves)]


def wallclock_rows() -> list[tuple]:
    """Measured jit wall-clock of the ragged 64-leaf sync on the
    multi-axis {pod: 2, data: 4} mesh: the PR-4-style serial path
    (stage-ordered dispatch, per-leaf means, fresh concat per pack) vs
    the overlapped runtime (merged wave dispatch, hoisted bucket mean,
    donated arenas, batched same-axis ring launches).  Two workloads:
    the standard mixed ragged pytree (bulk ring movement dominates —
    identical bytes either way, but batching collapses the per-bucket
    ring launches into one walk per axis) and the all-tail ragged
    pytree (dispatch-bound — the regime the overlapped runtime
    targets).
    Interleaved median-of-N timing; ``jax_*`` rows are recorded but not
    CI-gated (wall-clock noise).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import make_engine

    mesh = jax.make_mesh((2, 4), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    spec = P("pod", "data", None)

    def build(engine, sizes, leaves, *, shared_mean, arenas):
        c = _sync_program(sizes, engine, dict(HIER_SIZES),
                          shared_mean=shared_mean)
        n = len(sizes)
        if not arenas:
            def body(*ls):
                outs = c(*[l[0, 0] for l in ls])
                return tuple(o[None, None] for o in outs)
            fn = jax.jit(jax.shard_map(
                body, mesh=mesh, in_specs=(spec,) * n,
                out_specs=(spec,) * n, check_vma=False))

            def run():
                jax.block_until_ready(fn(*leaves))
            return c, run

        arena_bufs = c.make_arenas()

        def body(ar, *ls):
            outs, new_ar = c(*[l[0, 0] for l in ls], arenas=tuple(ar))
            return tuple(o[None, None] for o in outs) + tuple(new_ar)

        fn = jax.jit(jax.shard_map(
            body, mesh=mesh, in_specs=(P(),) + (spec,) * n,
            out_specs=(spec,) * n + (P(),) * len(arena_bufs),
            check_vma=False), donate_argnums=(0,))
        state = {"arenas": jax.device_put(
            arena_bufs, NamedSharding(mesh, P()))}

        def run():
            res = fn(state["arenas"], *leaves)
            jax.block_until_ready(res)
            state["arenas"] = tuple(res[n:])
        return c, run

    out = []
    rng = np.random.default_rng(0)
    for tag, sizes, iters in (("mixed", _ragged_sizes(), 6),
                              ("tail", _tail_sizes(), 10)):
        leaves = [jnp.asarray(rng.standard_normal((2, 4, s))
                              .astype(np.float32)) for s in sizes]
        c_serial, run_serial = build(
            make_engine("acis", inner_axis="data", outer_axis="pod",
                        overlap_dispatch=False),
            sizes, leaves, shared_mean=False, arenas=False)
        c_over, run_over = build(
            make_engine("acis", inner_axis="data", outer_axis="pod",
                        batch_rings=True),
            sizes, leaves, shared_mean=True, arenas=True)
        run_serial(); run_over()               # compile + warm
        ts, to = [], []
        for _ in range(iters):                 # interleaved: cancels drift
            t0 = time.perf_counter(); run_serial()
            ts.append(time.perf_counter() - t0)
            t0 = time.perf_counter(); run_over()
            to.append(time.perf_counter() - t0)
        t_serial = float(np.median(ts))
        t_over = float(np.median(to))
        out += [
            (f"jax_execplan_sync{len(sizes)}_{tag}_wallclock_serial",
             t_serial * 1e6,
             f"stages={len(c_serial.stages)}"
             f",collectives={_collectives(c_serial)}"),
            (f"jax_execplan_sync{len(sizes)}_{tag}_wallclock_overlapped",
             t_over * 1e6,
             f"speedup={t_serial / t_over:.2f}"
             f",stages={len(c_over.stages)}"
             f",collectives={_collectives(c_over)}"
             f",arenas={len(c_over.arena_avals)}"),
        ]
        if tag == "mixed":
            no_arena = c_serial.pack_transient_bytes(arenas=False)
            with_arena = c_over.pack_transient_bytes(arenas=True)
            out += [
                (f"execplan_sync{len(sizes)}_pack_transient_noarena_bytes",
                 float(no_arena), "fresh concat: bucket + live leaves"),
                (f"execplan_sync{len(sizes)}_pack_transient_arena_bytes",
                 float(with_arena),
                 "donated in-place write"
                 f",ratio={no_arena / max(with_arena, 1):.2f}"),
            ]
    return out


def rows() -> list[tuple]:
    """CSV rows: program_time of the 64-leaf sync (per-leaf vs
    bucketized), the simulated overlap cross-check, the TIER_OVERLAP
    calibration fit, and the measured wall-clock A/B."""
    from repro.core import make_engine, netmodel
    from repro.cgra.simulate import SwitchSim

    sizes = _ragged_sizes()
    axis_sizes = {"data": AXIS_SIZE}

    per_leaf = _sync_program(
        sizes, make_engine("acis", bucket_bytes=0), axis_sizes)
    bucketized = _sync_program(sizes, make_engine("acis"), axis_sizes)

    t_pl = per_leaf.program_time()
    t_bk = bucketized.program_time()
    total = sum(sizes) * 4
    cap = netmodel.bucket_bytes(AXIS_SIZE)
    out = [
        (f"execplan_sync{N_LEAVES}_per_leaf", t_pl * 1e6,
         f"collectives={_collectives(per_leaf)}"
         f",stages={len(per_leaf.stages)}"),
        (f"execplan_sync{N_LEAVES}_bucketized", t_bk * 1e6,
         f"speedup={t_pl / t_bk:.2f}"
         f",collectives={_collectives(bucketized)}"
         f",min_buckets={-(-total // cap)}"
         f",waves={bucketized.plan.n_waves}"),
    ]

    # overlap cross-check: simulate a small bucketized sync end-to-end and
    # put the wave-overlapped latency next to program_time's prediction
    eng = make_engine("acis")
    small_sizes = _ragged_sizes(16)
    small = _sync_program(small_sizes, eng, {"data": 4})
    rng = np.random.default_rng(0)
    inputs = [rng.standard_normal((4, s)).astype(np.float32)
              for s in small_sizes]
    _, report = SwitchSim(eng.topology(axis_size=4)).run(small, *inputs)
    out.append((
        "execplan_sim_sync16_end_to_end", report.t_end * 1e6,
        f"analytic_us={(report.t_program_model or 0.0) * 1e6:.2f}"
        f",serial_us={report.t_sim * 1e6:.2f}"))

    # TIER_OVERLAP calibration: fitted fractions + post-fit envelope
    fitted, samples, worst = calibrate()
    committed = {t: netmodel.TIER_OVERLAP[t] for t in fitted}
    out.append((
        "execplan_tier_overlap_calibration", 0.0,
        ",".join(f"{t}={v:.2f}" for t, v in sorted(fitted.items()))
        + f",committed={committed}"
        + f",points={len(samples)},worst_err={worst:.1%}"))

    out += wallclock_rows()
    return out
