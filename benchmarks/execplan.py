"""Execution-planning benchmark: per-leaf vs bucketized gradient sync.

The compiler's Coalesce pass concatenates per-leaf reductions into
flat-buffer bucket collectives; the ExecutionPlan runtime overlaps
independent stages.  This module prices both against the analytic
:func:`repro.core.netmodel.program_time` on a ragged many-leaf gradient
pytree (the transformer shape: a few big matmul leaves, a long tail of
small biases/norms) and cross-checks the overlap model on the dataplane
simulator — the numbers CI tracks in ``BENCH_netmodel.json``.
"""

from __future__ import annotations

import numpy as np

N_LEAVES = 64
AXIS_SIZE = 8


def _ragged_sizes(n_leaves: int = N_LEAVES) -> list[int]:
    """Element counts of a transformer-ish gradient pytree: 1/8 large
    matmul leaves, the rest a ragged small tail (deterministic)."""
    rng = np.random.default_rng(7)
    sizes = []
    for i in range(n_leaves):
        if i % 8 == 0:
            sizes.append(int(rng.integers(1 << 18, 1 << 19)))   # 1-2 MB
        else:
            sizes.append(int(rng.integers(1 << 8, 1 << 13)))    # 1-32 KB
    return sizes


def _sync_program(sizes, engine, axis_sizes):
    import jax
    import jax.numpy as jnp

    from repro.core import tracing

    n_total = 1
    for v in axis_sizes.values():
        n_total *= v

    def sync(*gs):
        outs = []
        for g in gs:
            r = tracing.reduce(g, axis="auto")
            outs.append(tracing.map(lambda y: y / n_total, r, name="mean"))
        return tuple(outs)

    prog = tracing.trace(sync, name=f"sync[{len(sizes)}]",
                         num_inputs=len(sizes))
    avals = tuple(jax.ShapeDtypeStruct((s,), jnp.float32) for s in sizes)
    return engine.compile(prog, in_avals=avals, axis_size=axis_sizes)


def _collectives(compiled) -> int:
    return sum(1 for s in compiled.stages
               if s.kind not in ("map", "delivered"))


def rows() -> list[tuple]:
    """CSV rows: program_time of the 64-leaf sync, per-leaf vs bucketized,
    plus a simulated overlap cross-check."""
    from repro.core import make_engine, netmodel
    from repro.cgra.simulate import SwitchSim

    sizes = _ragged_sizes()
    axis_sizes = {"data": AXIS_SIZE}

    per_leaf = _sync_program(
        sizes, make_engine("acis", bucket_bytes=0), axis_sizes)
    bucketized = _sync_program(sizes, make_engine("acis"), axis_sizes)

    t_pl = per_leaf.program_time()
    t_bk = bucketized.program_time()
    total = sum(sizes) * 4
    cap = netmodel.bucket_bytes(AXIS_SIZE)
    out = [
        (f"execplan_sync{N_LEAVES}_per_leaf", t_pl * 1e6,
         f"collectives={_collectives(per_leaf)}"
         f",stages={len(per_leaf.stages)}"),
        (f"execplan_sync{N_LEAVES}_bucketized", t_bk * 1e6,
         f"speedup={t_pl / t_bk:.2f}"
         f",collectives={_collectives(bucketized)}"
         f",min_buckets={-(-total // cap)}"
         f",waves={bucketized.plan.n_waves}"),
    ]

    # overlap cross-check: simulate a small bucketized sync end-to-end and
    # put the wave-overlapped latency next to program_time's prediction
    eng = make_engine("acis")
    small_sizes = _ragged_sizes(16)
    small = _sync_program(small_sizes, eng, {"data": 4})
    rng = np.random.default_rng(0)
    inputs = [rng.standard_normal((4, s)).astype(np.float32)
              for s in small_sizes]
    _, report = SwitchSim(eng.topology(axis_size=4)).run(small, *inputs)
    out.append((
        "execplan_sim_sync16_end_to_end", report.t_end * 1e6,
        f"analytic_us={(report.t_program_model or 0.0) * 1e6:.2f}"
        f",serial_us={report.t_sim * 1e6:.2f}"))
    return out
